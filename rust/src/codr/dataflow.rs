//! CoDR stats-path simulation: walk the Fig 5a loop nest over the
//! encoded weight structures, counting SRAM/RF/DRAM accesses, ALU
//! operations (split by Δ precision), crossbar transfers and cycles.
//!
//! All counts are *exact* functions of the encoded weights and the loop
//! structure — the same quantities a cycle-by-cycle replay would sum, but
//! computed per spatial-tile *class* (interior / right edge / bottom edge
//! / corner share identical per-tile work) so whole VGG16 layers simulate
//! in milliseconds. The hot path ([`simulate_layer`]) never materializes
//! the bitstreams: sizes come from the histogram model and per-vector
//! metadata from the content-addressed [`memo`], with the seed pipeline
//! retained as [`simulate_layer_reference`] and pinned bit-for-bit by the
//! `invariance` tests.

use super::Codr;
use crate::arch::MemoryKind;
use crate::models::LayerSpec;
use crate::reuse::memo::{self, Fp128};
use crate::reuse::{transform_layer_ucr, UcrVector};
use crate::rle::{
    encode_layer_refs, CoderSpec, CompressionStats, EncodedLayer, LayerHistograms, RleParams,
};
use crate::sim::LayerResult;
use crate::tensor::Weights;
use crate::util::bench;
use std::sync::Arc;
use std::time::Instant;

/// Per-vector quantities the dataflow loop needs (derived once from the
/// UCR vectors + chosen RLE parameters, and memoized per distinct vector
/// by [`memo`]).
#[derive(Clone, Debug)]
pub struct VectorMeta {
    /// Encoded entries: uniques + count-overflow dummies.
    pub entries: u64,
    /// Entries whose Δ is encoded low-precision (includes dummies).
    pub entries_low: u64,
    /// Entries encoded full-precision (vector firsts + large Δs).
    pub entries_full: u64,
    /// Total decoded indexes (= non-zero weights).
    pub nnz: u64,
    /// Index count routed to each APE (`m_local`).
    pub per_ape: Vec<u64>,
}

impl VectorMeta {
    pub fn new(u: &UcrVector, delta_bits: u32, count_bits: u32, t_m: usize, kernel: usize) -> Self {
        let cap = (1u64 << count_bits) - 1;
        let mut entries = 0u64;
        for &c in &u.counts {
            // Continuation chunking: ⌈c / (2^r − 1)⌉ chunks per unique.
            entries += 1 + (c as u64 - 1) / cap;
        }
        let dummies = entries - u.uniques.len() as u64;
        let deltas = u.deltas();
        let mut low = dummies; // dummies are Δ=0 → always low precision
        let mut full = 0u64;
        for (i, &d) in deltas.iter().enumerate() {
            if i == 0 {
                full += 1; // vector-first absolute
            } else if (d as u32) < (1u32 << delta_bits) {
                low += 1;
            } else {
                full += 1;
            }
        }
        if u.uniques.is_empty() {
            full = 0;
        }
        let mut per_ape = vec![0u64; t_m];
        for &idx in &u.indexes {
            per_ape[idx as usize / kernel] += 1;
        }
        VectorMeta {
            entries,
            entries_low: low,
            entries_full: full,
            nnz: u.nnz() as u64,
            per_ape,
        }
    }
}

/// A spatial-tile class: `count` tiles of `ro×co` outputs each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct SpatialClass {
    pub ro: usize,
    pub co: usize,
    pub count: u64,
}

/// Partition `r_o × c_o` outputs into tiles of at most `t_ro × t_co`,
/// grouped into ≤4 classes (interior, right edge, bottom edge, corner).
pub(crate) fn spatial_classes(r_o: usize, c_o: usize, t_ro: usize, t_co: usize) -> Vec<SpatialClass> {
    let full_r = r_o / t_ro;
    let rem_r = r_o % t_ro;
    let full_c = c_o / t_co;
    let rem_c = c_o % t_co;
    let mut classes = Vec::new();
    let mut push = |ro: usize, co: usize, count: u64| {
        if ro > 0 && co > 0 && count > 0 {
            classes.push(SpatialClass { ro, co, count });
        }
    };
    push(t_ro, t_co, (full_r * full_c) as u64);
    push(t_ro, rem_c, full_r as u64);
    push(rem_r, t_co, full_c as u64);
    push(rem_r, rem_c, 1);
    classes
}

/// One tile-chunk's extraction state: a private histogram plus the
/// chunk's memo entries in tile-major order. Chunks of one layer merge
/// in chunk order ([`price_extracted`]) and reproduce the sequential
/// walk bit for bit — every histogram field is an integer sum.
///
/// Entries borrow from the process-wide arena-interned memo, so a chunk
/// is `'static` and freely crosses pool-task boundaries without cloning
/// anything per vector.
pub struct CodrExtract {
    pub hist: LayerHistograms,
    pub cached: Vec<&'static memo::CachedVector>,
}

/// Extract the m-tile range `[mt0, mt1)` of a layer: linearize each
/// per-input-channel vector into a reusable scratch buffer, fingerprint
/// it ONCE ([`Fp128::of_i8`] at extraction — shard selection, map
/// bucketing, and equality all reuse it), and resolve it through the
/// two-level memo. The flat `cached` table is tile-major: vector
/// (mt, n) sits at `(mt − mt0)·N + n`.
pub fn extract_chunk(
    design: &Codr,
    spec: &LayerSpec,
    weights: &Weights,
    mt0: usize,
    mt1: usize,
) -> CodrExtract {
    let t0 = Instant::now();
    let cfg = &design.cfg;
    assert_eq!(weights.shape(), &[spec.m, spec.n, spec.r_k, spec.r_k]);
    let kernel = spec.r_k * spec.r_k;
    let cache = memo::global();
    let data = weights.data();
    let mut hist = LayerHistograms::new(CoderSpec::new(cfg.t_m * kernel));
    let mut cached: Vec<&'static memo::CachedVector> =
        Vec::with_capacity((mt1 - mt0) * spec.n);
    let mut scratch: Vec<i8> = Vec::with_capacity(cfg.t_m * kernel);
    for mt in mt0..mt1 {
        let m0 = mt * cfg.t_m;
        let tm = cfg.t_m.min(spec.m - m0);
        // CoDR builds one vector per single input channel, so iterating
        // the channels directly equals transform_layer_ucr's n-tile walk
        // (the n-tiling only groups channels, it never merges them).
        for n in 0..spec.n {
            scratch.clear();
            // Kernel elements are contiguous in the [M,N,Kr,Kc]
            // layout — copy whole kernels per output channel.
            for m in m0..m0 + tm {
                let off = (m * spec.n + n) * kernel;
                scratch.extend_from_slice(&data[off..off + kernel]);
            }
            let fp = Fp128::of_i8(&scratch);
            let entry = cache.get_or_insert_keyed(fp, &scratch);
            hist.merge_vector(&entry.ucr, &entry.size);
            cached.push(entry);
        }
    }
    bench::phases().add_extract(t0.elapsed());
    CodrExtract { hist, cached }
}

/// The pricing back half: merge the chunks' histograms (chunk order),
/// search parameters, derive per-vector metadata through the memo, and
/// walk the loop nest.
pub fn price_extracted(design: &Codr, spec: &LayerSpec, chunks: &[&CodrExtract]) -> LayerResult {
    let t0 = Instant::now();
    let cfg = &design.cfg;
    let kernel = spec.r_k * spec.r_k;
    let mut hist = LayerHistograms::new(CoderSpec::new(cfg.t_m * kernel));
    for c in chunks {
        hist.merge(&c.hist);
    }
    let params = hist.best_params();
    let compression = hist.stats(params, spec.num_weights());
    let metas: Vec<Arc<VectorMeta>> = chunks
        .iter()
        .flat_map(|c| c.cached.iter())
        .map(|e| e.meta_for(params.delta_bits, params.count_bits, cfg.t_m, kernel))
        .collect();
    let res = simulate_loop_nest(design, spec, &metas, params, compression);
    bench::phases().add_price(t0.elapsed());
    res
}

/// Simulate one conv layer on the CoDR design. See module docs.
///
/// This is the memoized hot path: each tile's linearized weight vector
/// is fingerprinted once and looked up in the global [`memo`]
/// (transforming only distinct vectors), the layer's encoded size comes
/// from the histogram size model (no bitstreams are emitted — the model
/// is asserted bit-identical to emission), and per-vector dataflow
/// metadata is shared through the memo. Equivalent to one full-range
/// [`extract_chunk`] + [`price_extracted`]; the coordinator splits big
/// layers into several chunks over the pool instead.
pub fn simulate_layer(design: &Codr, spec: &LayerSpec, weights: &Weights) -> LayerResult {
    let n_m_tiles = spec.m.div_ceil(design.cfg.t_m);
    let chunk = extract_chunk(design, spec, weights, 0, n_m_tiles);
    price_extracted(design, spec, &[&chunk])
}

/// The seed implementation, kept verbatim as the oracle: transform every
/// vector, emit the real bitstreams, then walk the same loop nest. The
/// `invariance` integration test pins [`simulate_layer`] byte-for-byte
/// against this, and `codr bench` uses it as the pre-optimization
/// baseline.
pub fn simulate_layer_reference(design: &Codr, spec: &LayerSpec, weights: &Weights) -> LayerResult {
    let cfg = &design.cfg;
    let tiled = transform_layer_ucr(spec, weights, cfg.t_n, cfg.t_m);
    let coder_spec = CoderSpec::new(cfg.t_m * spec.r_k * spec.r_k);
    let all_vectors: Vec<&UcrVector> = tiled.iter().flatten().collect();
    let enc = encode_layer_refs(&all_vectors, coder_spec);
    simulate_encoded(design, spec, &tiled, &enc)
}

/// Inner simulation over pre-transformed tiles + encoded layer (shared
/// with tests that need to poke at the intermediate state).
pub(crate) fn simulate_encoded(
    design: &Codr,
    spec: &LayerSpec,
    tiled: &[Vec<UcrVector>],
    enc: &EncodedLayer,
) -> LayerResult {
    let cfg = &design.cfg;
    let kernel = spec.r_k * spec.r_k;
    let n_m_tiles = spec.m.div_ceil(cfg.t_m);
    let n_n_tiles = spec.n.div_ceil(cfg.t_n);
    debug_assert_eq!(tiled.len(), n_m_tiles * n_n_tiles);

    // Flattening the per-tile vectors in tile order yields the same
    // tile-major layout the hot path builds: vector (mt, n) at mt·N + n.
    let metas: Vec<VectorMeta> = tiled
        .iter()
        .flat_map(|vs| vs.iter())
        .map(|u| VectorMeta::new(u, enc.params.delta_bits, enc.params.count_bits, cfg.t_m, kernel))
        .collect();
    let refs: Vec<&VectorMeta> = metas.iter().collect();
    simulate_loop_nest(design, spec, &refs, enc.params, enc.stats(spec.num_weights()))
}

/// The Fig 5a loop nest over precomputed per-vector metadata.
///
/// `metas` is flat and tile-major — vector (m-tile `mt`, input channel
/// `n`) sits at `mt * N + n`, so a tile's vectors are the contiguous
/// slice starting at `mt * N + nt * T_N`. Generic over the metadata
/// handle so the hot path passes `Arc<VectorMeta>` (memo-shared) and the
/// reference path plain `&VectorMeta`.
fn simulate_loop_nest<M: std::ops::Deref<Target = VectorMeta>>(
    design: &Codr,
    spec: &LayerSpec,
    metas: &[M],
    params: RleParams,
    compression: CompressionStats,
) -> LayerResult {
    let cfg = &design.cfg;
    let n_m_tiles = spec.m.div_ceil(cfg.t_m);
    let n_n_tiles = spec.n.div_ceil(cfg.t_n);
    debug_assert_eq!(metas.len(), n_m_tiles * spec.n);

    let t_ro_eff = cfg.t_ro_eff(spec.r_k, spec.stride);
    let t_co_eff = cfg.t_co_eff(spec.r_k, spec.stride);
    let classes = spatial_classes(spec.r_o(), spec.r_o(), t_ro_eff, t_co_eff);
    let n_sp: u64 = classes.iter().map(|c| c.count).sum();
    let n_m_groups = n_m_tiles.div_ceil(cfg.t_pu);

    let mut res = LayerResult {
        layer: spec.name.clone(),
        compression,
        ..Default::default()
    };
    let mem = &mut res.mem;
    let alu = &mut res.alu;
    alu.delta_bits = params.delta_bits;
    alu.xbar_bits = 16;

    // --- Per-layer (loop-invariant) traffic -------------------------------
    let total_weight_bits = res.compression.encoded_bits as u64;
    // ① The compressed stream is re-read from Weight SRAM once per spatial
    // tile (weights are the cheap thing to re-read — §III-B). Accesses are
    // counted per decoded structure element (Δ + count per entry, one
    // index per repetition — the Fig 7 convention); energy is priced on
    // the stream bits, word-amortized (see `energy::price_layer`).
    let total_elements: u64 = metas.iter().map(|m| 2 * m.entries + m.nnz).sum();
    mem.record(MemoryKind::WeightSram, total_elements * n_sp, 0);
    mem.counter_mut(MemoryKind::WeightSram).bits += total_weight_bits * n_sp;
    // Weight RF is filled from the SRAM words once per spatial pass.
    mem.record(
        MemoryKind::WeightRf,
        (total_weight_bits * n_sp).div_ceil(design.mem.sram_word_bits as u64),
        design.mem.sram_word_bits as u64,
    );
    // ④ Fully output stationary: each output feature written exactly once.
    mem.record(MemoryKind::OutputSram, spec.output_features() as u64, 8);
    // DRAM: compressed weights + raw features, each moved once.
    mem.record(MemoryKind::Dram, 1, total_weight_bits);
    mem.record(MemoryKind::Dram, 1, spec.input_features() as u64 * 8);
    mem.record(MemoryKind::Dram, 1, spec.output_features() as u64 * 8);

    // --- Loop nest ---------------------------------------------------------
    // MLP-array multipliers available per MPE.
    let mults_per_mpe = (cfg.mults_per_pu / cfg.t_n).max(1);
    // Per-APE load accumulator, reused across every PU iteration (the
    // seed allocated it afresh inside the hot loop).
    let mut ape_load = vec![0u64; cfg.t_m];

    for class in &classes {
        // Input tile actually needed for this output tile.
        let t_ri_a = (class.ro - 1) * spec.stride + spec.r_k;
        let t_ci_a = (class.co - 1) * spec.stride + spec.r_k;
        let elems_in = (t_ri_a * t_ci_a) as u64;
        let elems_out = (class.ro * class.co) as u64;

        for g in 0..n_m_groups {
            for nt in 0..n_n_tiles {
                let t_n_actual = cfg.t_n.min(spec.n - nt * cfg.t_n);
                // ② Input tile fetched once per (spatial, m-group, n-tile),
                // shared by ALL PUs through the Input RF (Fig 5a).
                let in_reads = t_n_actual as u64 * elems_in;
                mem.record(MemoryKind::InputSram, class.count * in_reads, 8);
                // RF filled in 64-bit words (8 features per write).
                mem.record(
                    MemoryKind::InputRf,
                    (class.count * in_reads).div_ceil(8),
                    64,
                );

                let mut group_cycles = 0u64;
                for p in 0..cfg.t_pu {
                    let mt = g * cfg.t_pu + p;
                    if mt >= n_m_tiles {
                        break;
                    }
                    let base = mt * spec.n + nt * cfg.t_n;
                    let vec_metas = &metas[base..base + t_n_actual];
                    let mut pu_mpe_cycles = 0u64;
                    ape_load.fill(0);
                    for m in vec_metas {
                        // MLP array: every entry multiplies its Δ by the
                        // whole input tile; the matrix-matrix accumulator
                        // adds it to the running product.
                        alu.mults_low += class.count * m.entries_low * elems_in;
                        alu.mults_full += class.count * m.entries_full * elems_in;
                        alu.adds += class.count * m.entries * elems_in;
                        // The MLP array streams the tile from the Input RF
                        // in 64-bit words (8 operands per access) — wide,
                        // regular access is CoDR's RF advantage over the
                        // baselines' scalar gathers.
                        mem.record(
                            MemoryKind::InputRf,
                            (class.count * m.entries * elems_in).div_ceil(8),
                            64,
                        );
                        // Decoder reads structures from the Weight RF:
                        // Δ + count per entry, one index per repetition.
                        mem.record(
                            MemoryKind::WeightRf,
                            class.count * (2 * m.entries + m.nnz),
                            8,
                        );
                        // Selector routes one window per index to its APE.
                        alu.xbar_transfers += class.count * m.nnz * elems_out;
                        // APE: accumulate the window into the Output RF —
                        // read + write per index, in 64-bit words (two
                        // 32-bit partials per access).
                        alu.adds += class.count * m.nnz * elems_out;
                        mem.record(
                            MemoryKind::OutputRf,
                            class.count * 2 * m.nnz * elems_out.div_ceil(2),
                            64,
                        );
                        // MPE occupancy: ceil(tile/mults) cycles per entry
                        // for the multiply, plus one selector cycle per
                        // index (decode overlaps).
                        let mpe = m.entries * elems_in.div_ceil(mults_per_mpe as u64) + m.nnz;
                        pu_mpe_cycles = pu_mpe_cycles.max(mpe);
                        for (a, &c) in m.per_ape.iter().enumerate() {
                            ape_load[a] += c;
                        }
                    }
                    // Each APE accepts one window per cycle — MPEs racing
                    // to the same APE serialize on the interconnect.
                    let ape_max = ape_load.iter().copied().max().unwrap_or(0);
                    group_cycles = group_cycles.max(pu_mpe_cycles.max(ape_max));
                }
                res.cycles += class.count * group_cycles;
            }
        }
    }

    // Output RF → Output SRAM drain already counted (writes once). The
    // Output RF also pays one final read per output feature for the drain.
    mem.record(MemoryKind::OutputRf, spec.output_features() as u64, 32);

    res.finish(&design.cacti, &design.mem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{synthesize_weights, LayerKind};
    use crate::sim::Accelerator;
    use crate::util::rng::Rng;

    fn layer(n: usize, m: usize, r_i: usize, r_k: usize, stride: usize, pad: usize) -> LayerSpec {
        LayerSpec {
            name: "test".into(),
            kind: LayerKind::Conv,
            n,
            m,
            r_i,
            r_k,
            stride,
            pad,
            groups: 1,
            sigma_q: 15.0,
            zero_frac: 0.5,
        }
    }

    fn sim(spec: &LayerSpec, seed: u64) -> LayerResult {
        let mut rng = Rng::new(seed);
        let w = synthesize_weights(spec, &mut rng);
        Codr::default().simulate_layer(spec, &w)
    }

    #[test]
    fn spatial_classes_cover_output_exactly() {
        for (ro, co, t) in [(55, 55, 8), (13, 13, 8), (7, 7, 8), (16, 16, 8), (3, 3, 8)] {
            let cls = spatial_classes(ro, co, t, t);
            let covered: u64 = cls.iter().map(|c| (c.ro * c.co) as u64 * c.count).sum();
            assert_eq!(covered, (ro * co) as u64, "ro={ro} co={co}");
            assert!(cls.len() <= 4);
        }
    }

    #[test]
    fn output_features_written_exactly_once() {
        // The headline dataflow property: fully output stationary.
        let spec = layer(8, 16, 14, 3, 1, 1);
        let r = sim(&spec, 1);
        assert_eq!(r.mem.output_sram.accesses, spec.output_features() as u64);
    }

    #[test]
    fn input_fetch_count_matches_paper_formula() {
        // §III-B: input features are fetched M/(T_PU·T_M) times (with halo
        // overhead for the kernel skirt). M=64 → 64/32 = 2 passes.
        let spec = layer(4, 64, 16, 3, 1, 1);
        let r = sim(&spec, 2);
        let passes = (spec.m as f64 / 32.0).ceil();
        let base = spec.input_features() as f64 * passes;
        let reads = r.mem.input_sram.accesses as f64;
        // Halo factor for 8×8 tiles of a 3×3 kernel: (10/8)² ≈ 1.56.
        assert!(reads >= base, "reads {reads} < base {base}");
        assert!(reads <= base * 1.8, "reads {reads} vs base {base} halo too big");
    }

    #[test]
    fn doubling_m_doubles_input_passes() {
        let spec1 = layer(8, 32, 14, 3, 1, 1);
        let spec2 = layer(8, 256, 14, 3, 1, 1);
        let r1 = sim(&spec1, 3);
        let r2 = sim(&spec2, 3);
        // M=32 → 1 pass; M=256 → 8 passes.
        let ratio = r2.mem.input_sram.accesses as f64 / r1.mem.input_sram.accesses as f64;
        assert!((6.0..10.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn weight_traffic_scales_with_spatial_tiles() {
        // Weights are re-read once per spatial tile — the deliberate trade
        // (§III-B): cheap weight re-reads buy input/output stationarity.
        let small = layer(8, 8, 8, 3, 1, 1); // 8×8 out → 1 tile
        let big = layer(8, 8, 32, 3, 1, 1); // 32×32 out → 16 tiles
        let rs = sim(&small, 4);
        let rb = sim(&big, 4);
        let ratio = rb.mem.weight_sram.bits as f64 / rs.mem.weight_sram.bits as f64;
        assert!((14.0..18.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn sparser_weights_mean_fewer_multiplies() {
        let mut spec = layer(16, 16, 14, 3, 1, 1);
        spec.zero_frac = 0.2;
        let dense = sim(&spec, 5);
        spec.zero_frac = 0.9;
        let sparse = sim(&spec, 5);
        assert!(sparse.alu.mults() < dense.alu.mults());
        assert!(sparse.cycles < dense.cycles);
    }

    #[test]
    fn repetition_cuts_multiplies_not_adds() {
        // Limiting unique weights (more repetition) reduces scalar-matrix
        // multiplies while APE accumulations track nnz.
        let spec = layer(16, 16, 14, 3, 1, 1);
        let mut rng = Rng::new(6);
        let w = synthesize_weights(&spec, &mut rng);
        let mut w_lim = w.clone();
        crate::quant::limit_unique_weights(w_lim.data_mut(), 8);
        let codr = Codr::default();
        let r = codr.simulate_layer(&spec, &w);
        let r_lim = codr.simulate_layer(&spec, &w_lim);
        assert!(r_lim.alu.mults() < r.alu.mults());
    }

    #[test]
    fn dram_weight_traffic_is_compressed_size() {
        let spec = layer(8, 16, 14, 3, 1, 1);
        let r = sim(&spec, 7);
        let feat_bits = (spec.input_features() + spec.output_features()) as u64 * 8;
        assert_eq!(
            r.mem.dram.bits,
            r.compression.encoded_bits as u64 + feat_bits
        );
    }

    #[test]
    fn cycles_positive_and_bounded_by_serial_work() {
        let spec = layer(16, 32, 14, 3, 1, 1);
        let r = sim(&spec, 8);
        assert!(r.cycles > 0);
        // Parallel cycles can't exceed total MPE work done serially.
        let serial = r.alu.mults() + r.alu.adds;
        assert!(r.cycles < serial);
    }

    #[test]
    fn energy_breakdown_nonzero_components() {
        let spec = layer(16, 32, 14, 3, 1, 1);
        let r = sim(&spec, 9);
        assert!(r.energy.dram_uj > 0.0);
        assert!(r.energy.sram_uj > 0.0);
        assert!(r.energy.rf_uj > 0.0);
        assert!(r.energy.alu_uj > 0.0);
        assert!(r.energy.xbar_uj > 0.0);
    }

    #[test]
    fn memoized_path_equals_reference_bit_for_bit() {
        // Edge-heavy geometry (N, M not multiples of T_N/T_M) plus a
        // strided layer: the memoized, emission-free hot path must
        // reproduce the seed pipeline exactly, including energy.
        for (spec, seed) in [
            (layer(10, 14, 12, 3, 1, 1), 21u64),
            (layer(3, 9, 23, 11, 4, 0), 22),
            (layer(16, 16, 14, 3, 1, 1), 23),
        ] {
            let mut rng = Rng::new(seed);
            let w = synthesize_weights(&spec, &mut rng);
            let design = Codr::default();
            let fast = design.simulate_layer(&spec, &w);
            let oracle = simulate_layer_reference(&design, &spec, &w);
            assert_eq!(fast, oracle, "layer {} seed {seed}", spec.name);
            // And again, fully memo-warm.
            assert_eq!(design.simulate_layer(&spec, &w), oracle);
        }
    }

    #[test]
    fn chunked_extraction_equals_whole_layer_bit_for_bit() {
        // The coordinator splits big layers into m-tile chunk tasks;
        // any split must price to the identical LayerResult (mem, alu,
        // cycles, compression, energy), including clipped edge tiles.
        for (spec, seed) in [
            (layer(10, 14, 12, 3, 1, 1), 31u64),
            (layer(16, 37, 14, 3, 1, 1), 32), // M not a multiple of T_M
            (layer(3, 9, 23, 11, 4, 0), 33),
        ] {
            let mut rng = Rng::new(seed);
            let w = synthesize_weights(&spec, &mut rng);
            let design = Codr::default();
            let whole = design.simulate_layer(&spec, &w);
            let n_m_tiles = spec.m.div_ceil(design.cfg.t_m);
            for n_chunks in [1usize, 2, 3, n_m_tiles] {
                if n_chunks == 0 || n_chunks > n_m_tiles {
                    continue;
                }
                let chunks: Vec<CodrExtract> = (0..n_chunks)
                    .map(|ci| {
                        extract_chunk(
                            &design,
                            &spec,
                            &w,
                            n_m_tiles * ci / n_chunks,
                            n_m_tiles * (ci + 1) / n_chunks,
                        )
                    })
                    .collect();
                let refs: Vec<&CodrExtract> = chunks.iter().collect();
                assert_eq!(
                    price_extracted(&design, &spec, &refs),
                    whole,
                    "layer {} seed {seed} split {n_chunks}",
                    spec.name
                );
            }
        }
    }

    #[test]
    fn alexnet_conv1_strided_tiling() {
        // 11×11 stride 4: T_RO_eff = 3, so the 55×55 output needs
        // ceil(55/3)² = 361 spatial tiles; the sim must not blow up.
        let spec = layer(3, 96, 227, 11, 4, 0);
        let r = sim(&spec, 10);
        assert!(r.cycles > 0);
        assert_eq!(r.mem.output_sram.accesses, spec.output_features() as u64);
    }
}
