//! CACTI-lite: analytical per-access SRAM/RF energy model.
//!
//! The paper models SRAM cells with CACTI 6.0 [12] at 45 nm and takes
//! DRAM at 160 pJ/B [5]. CACTI itself is not available offline, so we use
//! the standard analytical decomposition its reports follow:
//!
//! `E(access) = α·√(capacity) + β·width`
//!
//! — the first term is the H-tree/decode/sense cost that grows with the
//! array's physical extent, the second the per-bit I/O cost. α and β are
//! calibrated against published CACTI 45 nm numbers (≈6 pJ for a 64-bit
//! read of an 8 KB array, ≈36 pJ for 256 KB), which reproduces the
//! relative weight-vs-feature access costs that drive the paper's §V-C
//! argument: compressed weights stream through 64-bit words that amortize
//! the array cost over ~38 weights, while features pay a full (smaller)
//! access each.

/// Energy model with calibration constants (pJ).
#[derive(Clone, Copy, Debug)]
pub struct CactiLite {
    /// pJ per √kB of array capacity per access.
    pub alpha_sram: f64,
    /// pJ per bit of access width (SRAM I/O).
    pub beta_sram: f64,
    /// RF flat cost per access (pJ).
    pub alpha_rf: f64,
    /// RF per-bit cost (pJ/bit).
    pub beta_rf: f64,
    /// DRAM energy (pJ per byte) — the paper's 160 pJ/B.
    pub dram_pj_per_byte: f64,
    /// Energy of a full-precision 8×8-bit multiply (pJ, 45 nm).
    pub mult8_pj: f64,
    /// Energy of a 32-bit accumulate (pJ).
    pub add32_pj: f64,
    /// Energy of one crossbar traversal (pJ) per `width` bits.
    pub xbar_pj_per_bit: f64,
}

impl Default for CactiLite {
    fn default() -> Self {
        CactiLite {
            alpha_sram: 2.0,
            beta_sram: 0.5,
            alpha_rf: 0.1,
            beta_rf: 0.02,
            dram_pj_per_byte: 160.0,
            // ≈1 pJ for an 8×8 multiply incl. operand movement at 45 nm
            // (Horowitz ISSCC'14 scaled up from 32 nm); the paper's ALU
            // share (≈42% of CoDR energy, §V-D) pins the useful range.
            mult8_pj: 1.0,
            add32_pj: 0.15,
            xbar_pj_per_bit: 0.012,
        }
    }
}

impl CactiLite {
    /// Energy (pJ) of one SRAM access of `width_bits` on a `size_kb` array.
    pub fn sram_access_pj(&self, size_kb: f64, width_bits: u32) -> f64 {
        self.alpha_sram * size_kb.sqrt() + self.beta_sram * width_bits as f64
    }

    /// Energy (pJ) of one register-file access of `width_bits`.
    pub fn rf_access_pj(&self, width_bits: u32) -> f64 {
        self.alpha_rf + self.beta_rf * width_bits as f64
    }

    /// DRAM transfer energy (pJ) for `bits` of traffic.
    pub fn dram_pj(&self, bits: u64) -> f64 {
        self.dram_pj_per_byte * bits as f64 / 8.0
    }

    /// Multiply energy scaled by operand width: an `a×b`-bit multiply
    /// costs `(a·b)/(8·8)` of a full 8×8 multiply (array-multiplier area
    /// scaling — this is what makes differential computation on small Δs
    /// cheaper, §II-C).
    pub fn mult_pj(&self, a_bits: u32, b_bits: u32) -> f64 {
        self.mult8_pj * (a_bits as f64 * b_bits as f64) / 64.0
    }

    /// Crossbar traversal energy for a `width_bits` flit.
    pub fn xbar_pj(&self, width_bits: u32) -> f64 {
        self.xbar_pj_per_bit * width_bits as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_energy_grows_with_size_and_width() {
        let c = CactiLite::default();
        assert!(c.sram_access_pj(250.0, 64) > c.sram_access_pj(200.0, 64));
        assert!(c.sram_access_pj(250.0, 64) > c.sram_access_pj(250.0, 8));
    }

    #[test]
    fn calibration_anchors() {
        let c = CactiLite::default();
        // ≈38 pJ for a 64-bit read of a 250 kB array (CACTI 45 nm ballpark).
        let e = c.sram_access_pj(250.0, 64);
        assert!((30.0..80.0).contains(&e), "250kB/64b = {e}");
        // A small 8 KB array is several times cheaper.
        assert!(e / c.sram_access_pj(8.0, 64) > 1.2);
    }

    /// §V-C: the per-*useful-datum* cost ratio between an 8-bit feature
    /// access and a compressed weight streamed in 64-bit words should be
    /// large (the paper reports 20.61× for CoDR at 1.69 bits/weight).
    #[test]
    fn weight_vs_feature_cost_ratio_order_of_magnitude() {
        let c = CactiLite::default();
        let feature = c.sram_access_pj(250.0, 8);
        let weight_word = c.sram_access_pj(200.0, 64);
        let bits_per_weight = 1.69;
        let per_weight = weight_word * bits_per_weight / 64.0;
        let ratio = feature / per_weight;
        assert!(
            (10.0..40.0).contains(&ratio),
            "feature/weight per-access ratio {ratio}"
        );
    }

    #[test]
    fn dram_energy_is_160pj_per_byte() {
        let c = CactiLite::default();
        assert_eq!(c.dram_pj(8), 160.0);
        assert_eq!(c.dram_pj(64), 8.0 * 160.0);
    }

    #[test]
    fn small_delta_multiplies_are_cheaper() {
        let c = CactiLite::default();
        // 2-bit Δ × 8-bit feature = 1/4 the energy of 8×8.
        assert!((c.mult_pj(2, 8) - c.mult8_pj * 0.25).abs() < 1e-12);
        assert!(c.mult_pj(8, 8) > c.mult_pj(4, 8));
    }

    #[test]
    fn rf_much_cheaper_than_sram() {
        let c = CactiLite::default();
        assert!(c.sram_access_pj(250.0, 8) / c.rf_access_pj(8) > 5.0);
    }
}
