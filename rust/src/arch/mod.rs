//! Shared architecture substrate: design configurations (paper Table I),
//! the memory-hierarchy access counters, and the CACTI-lite energy model.

pub mod cacti;
pub mod mem;

pub use cacti::CactiLite;
pub use mem::{AccessCounter, MemoryKind, MemoryStats};

/// Tiling configuration of one RTL design — paper **Table I**. All three
/// designs are sized to the same 2.85 mm² (45 nm) by choosing `T_PU`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileConfig {
    pub name: &'static str,
    /// Number of processing units.
    pub t_pu: usize,
    /// Output channels per PU iteration.
    pub t_m: usize,
    /// Input channels per PU cycle.
    pub t_n: usize,
    /// Output tile rows / cols per PU.
    pub t_ro: usize,
    pub t_co: usize,
    /// Input tile rows / cols held in the Input RF.
    pub t_ri: usize,
    pub t_ci: usize,
    /// Multipliers per PU ("× per PU" row of Table I).
    pub mults_per_pu: usize,
}

impl TileConfig {
    /// CoDR column of Table I.
    pub const fn codr() -> Self {
        TileConfig {
            name: "CoDR",
            t_pu: 8,
            t_m: 4,
            t_n: 4,
            t_ro: 8,
            t_co: 8,
            t_ri: 20,
            t_ci: 20,
            mults_per_pu: 64,
        }
    }

    /// UCNN column of Table I.
    pub const fn ucnn() -> Self {
        TileConfig {
            name: "UCNN",
            t_pu: 48,
            t_m: 1,
            t_n: 4,
            t_ro: 1,
            t_co: 8,
            t_ri: 1,
            t_ci: 12,
            mults_per_pu: 8,
        }
    }

    /// SCNN column of Table I.
    pub const fn scnn() -> Self {
        TileConfig {
            name: "SCNN",
            t_pu: 21,
            t_m: 2,
            t_n: 1,
            t_ro: 1,
            t_co: 1,
            t_ri: 1,
            t_ci: 1,
            mults_per_pu: 16,
        }
    }

    /// Total multipliers across the accelerator.
    pub fn total_mults(&self) -> usize {
        self.t_pu * self.mults_per_pu
    }

    /// Effective output tile rows for a layer: the Input RF bounds how many
    /// output rows a pass can produce (`T_RO_eff = ⌊(T_RI − R_K)/stride⌋+1`,
    /// clipped to `T_RO`). E.g. AlexNet conv1 (11×11, stride 4) fits only
    /// 3×3 outputs in CoDR's 20×20 Input RF tile.
    pub fn t_ro_eff(&self, r_k: usize, stride: usize) -> usize {
        if self.t_ri < r_k {
            1
        } else {
            ((self.t_ri - r_k) / stride + 1).clamp(1, self.t_ro)
        }
    }

    pub fn t_co_eff(&self, c_k: usize, stride: usize) -> usize {
        if self.t_ci < c_k {
            1
        } else {
            ((self.t_ci - c_k) / stride + 1).clamp(1, self.t_co)
        }
    }
}

/// SRAM provisioning shared by all three designs (paper §V-A): 250 kB for
/// input features, 250 kB for output features, 200 kB for weights; DRAM
/// access energy 160 pJ/B; overall area 2.85 mm² at 45 nm.
#[derive(Clone, Copy, Debug)]
pub struct MemConfig {
    pub input_sram_kb: f64,
    pub output_sram_kb: f64,
    pub weight_sram_kb: f64,
    /// Word width of every SRAM port (bits).
    pub sram_word_bits: u32,
    /// DRAM access energy, pJ per byte.
    pub dram_pj_per_byte: f64,
    /// Register-file size per PE (bytes) — sets the RF per-access energy.
    pub rf_bytes: f64,
}

impl Default for MemConfig {
    fn default() -> Self {
        MemConfig {
            input_sram_kb: 250.0,
            output_sram_kb: 250.0,
            weight_sram_kb: 200.0,
            sram_word_bits: 64,
            dram_pj_per_byte: 160.0,
            rf_bytes: 2048.0,
        }
    }
}

/// Total area the paper equalizes across designs.
pub const TOTAL_AREA_MM2: f64 = 2.85;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values_match_paper() {
        let c = TileConfig::codr();
        assert_eq!((c.t_pu, c.t_m, c.t_n), (8, 4, 4));
        assert_eq!((c.t_ro, c.t_co, c.t_ri, c.t_ci), (8, 8, 20, 20));
        assert_eq!(c.mults_per_pu, 64);
        let u = TileConfig::ucnn();
        assert_eq!((u.t_pu, u.t_m, u.t_n), (48, 1, 4));
        assert_eq!((u.t_ro, u.t_co, u.t_ri, u.t_ci), (1, 8, 1, 12));
        assert_eq!(u.mults_per_pu, 8);
        let s = TileConfig::scnn();
        assert_eq!((s.t_pu, s.t_m, s.t_n), (21, 2, 1));
        assert_eq!(s.mults_per_pu, 16);
    }

    #[test]
    fn total_mults_comparable_across_designs() {
        // Equal-area designs end up with the same order of multipliers.
        assert_eq!(TileConfig::codr().total_mults(), 512);
        assert_eq!(TileConfig::ucnn().total_mults(), 384);
        assert_eq!(TileConfig::scnn().total_mults(), 336);
    }

    #[test]
    fn effective_output_tile_respects_input_rf() {
        let c = TileConfig::codr();
        // 3×3 stride 1: (20-3)/1+1 = 18 → clipped to 8.
        assert_eq!(c.t_ro_eff(3, 1), 8);
        // 11×11 stride 4 (AlexNet conv1): (20-11)/4+1 = 3.
        assert_eq!(c.t_ro_eff(11, 4), 3);
        // 5×5 stride 1: (20-5)+1 = 16 → 8.
        assert_eq!(c.t_ro_eff(5, 1), 8);
        // 7×7 stride 2 (GoogleNet conv1): (20-7)/2+1 = 7.
        assert_eq!(c.t_ro_eff(7, 2), 7);
        // Degenerate: kernel larger than the RF tile.
        assert_eq!(c.t_ro_eff(25, 1), 1);
    }

    #[test]
    fn mem_config_defaults_match_paper() {
        let m = MemConfig::default();
        assert_eq!(m.input_sram_kb, 250.0);
        assert_eq!(m.output_sram_kb, 250.0);
        assert_eq!(m.weight_sram_kb, 200.0);
        assert_eq!(m.dram_pj_per_byte, 160.0);
    }
}
