//! Memory-hierarchy access accounting.
//!
//! Every simulator (CoDR, UCNN, SCNN) records its traffic here; the
//! energy model then prices each class with [`super::CactiLite`]. Keeping
//! a single accounting structure guarantees Fig 7 (SRAM accesses) and
//! Fig 8 (energy) are computed identically across designs.

/// One storage structure's traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AccessCounter {
    pub accesses: u64,
    pub bits: u64,
}

impl AccessCounter {
    #[inline]
    pub fn record(&mut self, accesses: u64, bits_per_access: u64) {
        self.accesses += accesses;
        self.bits += accesses * bits_per_access;
    }

    pub fn add(&mut self, o: &AccessCounter) {
        self.accesses += o.accesses;
        self.bits += o.bits;
    }
}

/// Storage classes distinguished by the evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemoryKind {
    /// 250 kB input-feature SRAM.
    InputSram,
    /// 250 kB output-feature SRAM.
    OutputSram,
    /// 200 kB (compressed) weight SRAM.
    WeightSram,
    /// Off-chip DRAM.
    Dram,
    /// Input register file (shared across PUs in CoDR).
    InputRf,
    /// Weight RF inside each MPE.
    WeightRf,
    /// Output RF inside each APE.
    OutputRf,
}

/// Full traffic breakdown of one simulated layer (or an aggregate).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemoryStats {
    pub input_sram: AccessCounter,
    pub output_sram: AccessCounter,
    pub weight_sram: AccessCounter,
    pub dram: AccessCounter,
    pub input_rf: AccessCounter,
    pub weight_rf: AccessCounter,
    pub output_rf: AccessCounter,
}

impl MemoryStats {
    pub fn counter_mut(&mut self, kind: MemoryKind) -> &mut AccessCounter {
        match kind {
            MemoryKind::InputSram => &mut self.input_sram,
            MemoryKind::OutputSram => &mut self.output_sram,
            MemoryKind::WeightSram => &mut self.weight_sram,
            MemoryKind::Dram => &mut self.dram,
            MemoryKind::InputRf => &mut self.input_rf,
            MemoryKind::WeightRf => &mut self.weight_rf,
            MemoryKind::OutputRf => &mut self.output_rf,
        }
    }

    pub fn counter(&self, kind: MemoryKind) -> &AccessCounter {
        match kind {
            MemoryKind::InputSram => &self.input_sram,
            MemoryKind::OutputSram => &self.output_sram,
            MemoryKind::WeightSram => &self.weight_sram,
            MemoryKind::Dram => &self.dram,
            MemoryKind::InputRf => &self.input_rf,
            MemoryKind::WeightRf => &self.weight_rf,
            MemoryKind::OutputRf => &self.output_rf,
        }
    }

    #[inline]
    pub fn record(&mut self, kind: MemoryKind, accesses: u64, bits_per_access: u64) {
        self.counter_mut(kind).record(accesses, bits_per_access);
    }

    /// Total on-chip SRAM accesses — the Fig 7 metric.
    pub fn sram_accesses(&self) -> u64 {
        self.input_sram.accesses + self.output_sram.accesses + self.weight_sram.accesses
    }

    /// Total on-chip SRAM traffic in bits.
    pub fn sram_bits(&self) -> u64 {
        self.input_sram.bits + self.output_sram.bits + self.weight_sram.bits
    }

    /// Fraction of SRAM bandwidth (bits) spent on weights — the paper
    /// reports 50% for CoDR, 1.40% for UCNN.
    pub fn weight_bw_fraction(&self) -> f64 {
        let total = self.sram_bits();
        if total == 0 {
            0.0
        } else {
            self.weight_sram.bits as f64 / total as f64
        }
    }

    pub fn rf_accesses(&self) -> u64 {
        self.input_rf.accesses + self.weight_rf.accesses + self.output_rf.accesses
    }

    pub fn add(&mut self, o: &MemoryStats) {
        self.input_sram.add(&o.input_sram);
        self.output_sram.add(&o.output_sram);
        self.weight_sram.add(&o.weight_sram);
        self.dram.add(&o.dram);
        self.input_rf.add(&o.input_rf);
        self.weight_rf.add(&o.weight_rf);
        self.output_rf.add(&o.output_rf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates_bits() {
        let mut c = AccessCounter::default();
        c.record(10, 8);
        c.record(5, 64);
        assert_eq!(c.accesses, 15);
        assert_eq!(c.bits, 80 + 320);
    }

    #[test]
    fn stats_route_by_kind() {
        let mut s = MemoryStats::default();
        s.record(MemoryKind::InputSram, 3, 8);
        s.record(MemoryKind::WeightSram, 2, 64);
        s.record(MemoryKind::Dram, 1, 1024);
        assert_eq!(s.input_sram.accesses, 3);
        assert_eq!(s.weight_sram.bits, 128);
        assert_eq!(s.dram.bits, 1024);
        assert_eq!(s.sram_accesses(), 5);
        assert_eq!(s.sram_bits(), 24 + 128);
    }

    #[test]
    fn weight_bw_fraction() {
        let mut s = MemoryStats::default();
        s.record(MemoryKind::InputSram, 10, 8);
        s.record(MemoryKind::WeightSram, 10, 8);
        assert!((s.weight_bw_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn add_merges_all_classes() {
        let mut a = MemoryStats::default();
        a.record(MemoryKind::OutputRf, 7, 32);
        let mut b = MemoryStats::default();
        b.record(MemoryKind::OutputRf, 3, 32);
        b.record(MemoryKind::InputRf, 1, 8);
        a.add(&b);
        assert_eq!(a.output_rf.accesses, 10);
        assert_eq!(a.input_rf.accesses, 1);
        assert_eq!(a.rf_accesses(), 11);
    }

    #[test]
    fn empty_stats_fraction_is_zero() {
        assert_eq!(MemoryStats::default().weight_bw_fraction(), 0.0);
    }
}
