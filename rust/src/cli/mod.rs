//! Command-line interface (hand-rolled — `clap` is unavailable in the
//! offline registry).
//!
//! ```text
//! codr figure <fig2|table1|fig6|fig7|fig8|headline|detail|all> [opts]
//! codr simulate --model <name> [--arch <CoDR|UCNN|SCNN>] [opts]
//! codr map --model <name> [--layer L] [--group G] [--quick] [--json] [opts]
//! codr compress --model <name> [--seed N]
//! codr golden [--artifacts DIR] [--seed N]
//! codr serve [--addr HOST:PORT] [--store DIR] [--store-cap-mb N] [--drain-secs N]
//!           [--conn-timeout-secs N] [--max-queued N] [--ring host:port,host:port,...]
//! codr submit [--addr HOST:PORT] [grid opts] [--watch | --wait] [--retries N]
//! codr watch --job N [--addr HOST:PORT] [--retries N]
//! codr ring [--addr HOST:PORT] [--model NAME [--group G] [--seed N]]
//! codr warm [--addr HOST:PORT | --store DIR] [grid opts]
//! codr bench [--quick] [--out FILE] [grid opts]
//! codr analyze [--json] [--src DIR] [--print-env-table]
//! codr info
//! ```

mod args;
pub mod commands;

pub use args::Args;

use anyhow::{bail, Result};

const USAGE: &str = "\
CoDR: Computation and Data Reuse Aware CNN Accelerator — reproduction CLI

USAGE:
    codr <COMMAND> [OPTIONS]

COMMANDS:
    figure <id>     Regenerate a paper figure/table:
                    fig2 | table1 | fig6 | fig7 | fig8 | headline | detail | all
                    (reads/writes the result store; --fresh bypasses it)
    simulate        Simulate one model on one design, print per-layer stats
    map             Search one layer's mapping space (data-centric
                    directives), print the Pareto front over
                    (SRAM accesses, energy, PE utilization)
    compress        Compress one model with the customized RLE, print stats
    golden          Verify the CoDR datapath against the XLA golden model
                    (needs a build with --features pjrt)
    serve           Run the persistent sweep service (TCP, line-JSON)
    submit          Send a sweep grid to a running server
                    (--watch to stream progress, --wait to poll)
    watch           Stream a submitted job's per-point progress (--job N)
    ring            Show a ring-mode server's membership, peer health,
                    and forward/repair gauges (--model resolves an owner)
    warm            Populate the result store (locally, or via --addr)
    bench           Time the simulation hot path (reference vs memoized),
                    write BENCH_hotpath.json
    analyze         Statically check project invariants over rust/src
                    (lock order, atomics, panic policy, fault seams,
                    env registry); exit 2 if findings remain
    info            Print design configurations and model zoo summary

OPTIONS:
    --models a,b,c     Models to evaluate (default: alexnet,vgg16,googlenet)
    --model NAME       Single model (simulate/compress)
    --arch NAME        Design: CoDR | UCNN | SCNN   (default CoDR)
    --archs a,b        Designs for serve/warm grids (default all)
    --groups g1,g2     Sweep groups: U=16,U=64,Orig,D=75%,D=50%,D=25%
    --seed N           Workload seed                (default 42)
    --artifacts DIR    Artifact directory           (default artifacts)
    --store DIR        Result store ($CODR_STORE, default results/store)
    --store-cap-mb N   serve: store size cap in MiB (oldest packs evicted)
    --drain-secs N     serve: shutdown drain bound in seconds (default 30)
    --conn-timeout-secs N
                       serve: idle-connection timeout (0 = unbounded)
    --max-queued N     serve: admission-queue bound; past it, submit/warm/map
                       answer state:\"queued-full\" (default 64)
    --addr HOST:PORT   Sweep service address        (default 127.0.0.1:7878)
    --ring a,b,...     serve: static multi-host ring membership (all nodes,
                       including this one; $CODR_RING). Submits for packs
                       another node owns are forwarded there; a down owner
                       degrades to local compute + anti-entropy repair
    --retries N        submit/watch/map: retry transport failures and
                       queued-full refusals with exponential backoff
                       (default 0 = fail fast)
    --job N            watch: job id to attach to
    --layer NAME       map: conv layer to search (default: first conv)
    --group G          map: single sweep group      (default Orig)
    --max-candidates N map: cap on evaluated mappings (default 512)
    --json             map: emit the report as JSON instead of a table
    --fresh            Ignore the result store for this run
    --watch            submit: stream per-point progress until done
    --wait             submit: poll until the job finishes
    --save             Also write reports under results/
    --quick            bench/map: tiny grid for CI smoke runs
    --out FILE         bench: output path (default BENCH_hotpath.json)
    --src DIR          analyze: source root to scan (default rust/src)
    --json             analyze: machine-readable findings report
";

/// A command's rendered output plus the process exit code it asks for.
/// Almost everything exits 0 on success; `analyze` exits 2 when the
/// tree has findings (the report itself rendered fine — the nonzero
/// code is the verdict, and it must not trigger the usage dump).
pub struct Outcome {
    pub text: String,
    pub code: i32,
}

impl Outcome {
    fn ok(text: String) -> Outcome {
        Outcome { text, code: 0 }
    }
}

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    match dispatch(argv) {
        Ok(out) => {
            println!("{}", out.text);
            out.code
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            eprintln!("\n{USAGE}");
            1
        }
    }
}

fn dispatch(argv: &[String]) -> Result<Outcome> {
    if argv.is_empty() {
        bail!("missing command");
    }
    let cmd = argv[0].as_str();
    let rest = &argv[1..];
    match cmd {
        "figure" => {
            if rest.is_empty() {
                bail!("figure: missing id");
            }
            let args = Args::parse(&rest[1..])?;
            commands::figure(&rest[0], &args).map(Outcome::ok)
        }
        "simulate" => commands::simulate(&Args::parse(rest)?).map(Outcome::ok),
        "map" => commands::map(&Args::parse(rest)?).map(Outcome::ok),
        "compress" => commands::compress(&Args::parse(rest)?).map(Outcome::ok),
        "golden" => commands::golden(&Args::parse(rest)?).map(Outcome::ok),
        "serve" => commands::serve(&Args::parse(rest)?).map(Outcome::ok),
        "submit" => commands::submit(&Args::parse(rest)?).map(Outcome::ok),
        "watch" => commands::watch(&Args::parse(rest)?).map(Outcome::ok),
        "ring" => commands::ring(&Args::parse(rest)?).map(Outcome::ok),
        "warm" => commands::warm(&Args::parse(rest)?).map(Outcome::ok),
        "bench" => commands::bench(&Args::parse(rest)?).map(Outcome::ok),
        "analyze" => commands::analyze(&Args::parse(rest)?),
        "info" => Ok(Outcome::ok(commands::info())),
        "help" | "--help" | "-h" => Ok(Outcome::ok(USAGE.to_string())),
        other => bail!("unknown command `{other}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn help_renders() {
        assert!(dispatch(&sv(&["help"])).unwrap().text.contains("COMMANDS"));
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&sv(&["bogus"])).is_err());
        assert!(dispatch(&[]).is_err());
    }

    #[test]
    fn table1_via_cli() {
        let out = dispatch(&sv(&["figure", "table1"])).unwrap();
        assert!(out.text.contains("T_PU"));
    }

    #[test]
    fn info_lists_models() {
        let out = dispatch(&sv(&["info"])).unwrap();
        assert!(out.text.contains("alexnet") && out.text.contains("googlenet"));
    }
}
