//! CLI command implementations.

use super::Args;
use crate::coordinator::{run_sweep_with, Arch, SweepResults, SweepStats};
use crate::models::Workload;
use crate::report;
use crate::serve::{proto, ResultStore, Server};
use crate::sim::simulate_model;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Run the figure sweep through the result store (unless `--fresh`), so
/// repeated figure invocations reuse every previously simulated point.
/// Cache statistics go to stderr: stdout must stay byte-identical between
/// cold and warm runs.
fn figure_sweep(args: &Args, models: &[crate::models::Model]) -> Result<SweepResults> {
    let seed = args.seed()?;
    let groups = args.groups()?;
    if args.flag("fresh") {
        return Ok(run_sweep_with(models, &groups, &Arch::all(), seed, None));
    }
    match ResultStore::open(args.store_dir()) {
        Ok(store) => {
            let results = run_sweep_with(models, &groups, &Arch::all(), seed, Some(&store));
            eprintln!(
                "sweep: {} (store: {})",
                render_stats(&results.stats),
                store.dir().display()
            );
            Ok(results)
        }
        Err(e) => {
            // An unusable store must never block a figure.
            eprintln!("warn: result store unavailable ({e:#}); running uncached");
            Ok(run_sweep_with(models, &groups, &Arch::all(), seed, None))
        }
    }
}

/// `codr figure <id>` — regenerate a paper figure/table.
pub fn figure(id: &str, args: &Args) -> Result<String> {
    let seed = args.seed()?;
    let models = args.models()?;
    let groups = args.groups()?;
    let model_names: Vec<&str> = models.iter().map(|m| m.name).collect();

    let needs_sweep = matches!(id, "fig6" | "fig7" | "fig8" | "headline" | "detail" | "all");
    let sweep = if needs_sweep {
        Some(figure_sweep(args, &models)?)
    } else {
        None
    };

    let mut out = String::new();
    let mut saved = Vec::new();
    let mut emit = |name: &str, text: String, save: bool| {
        if save {
            if let Ok(p) = report::write_results_file(&format!("{name}.txt"), &text) {
                saved.push(p.display().to_string());
            }
        }
        out.push_str(&text);
        out.push('\n');
    };

    let save = args.flag("save");
    match id {
        "fig2" => emit("fig2", report::fig2_report(&models, seed), save),
        "table1" => emit("table1", report::table1_report(), save),
        "fig6" => emit(
            "fig6",
            report::fig6_report(sweep.as_ref().unwrap(), &model_names, &groups),
            save,
        ),
        "fig7" => {
            // The paper plots GoogleNet; honor --models for subsets.
            let model = model_names.last().copied().unwrap_or("googlenet");
            emit(
                "fig7",
                report::fig7_report(sweep.as_ref().unwrap(), model, &groups),
                save,
            )
        }
        "fig8" => emit(
            "fig8",
            report::fig8_report(sweep.as_ref().unwrap(), &model_names, &groups),
            save,
        ),
        "headline" => emit(
            "headline",
            report::headline_report(sweep.as_ref().unwrap(), &model_names)?,
            save,
        ),
        "detail" => {
            let s = sweep.as_ref().unwrap();
            for m in &models {
                emit(
                    &format!("detail_{}", m.name),
                    report::sram_detail_report(s, m),
                    save,
                );
            }
        }
        "all" => {
            let s = sweep.as_ref().unwrap();
            emit("fig2", report::fig2_report(&models, seed), save);
            emit("table1", report::table1_report(), save);
            emit("fig6", report::fig6_report(s, &model_names, &groups), save);
            let f7model = model_names.last().copied().unwrap_or("googlenet");
            emit("fig7", report::fig7_report(s, f7model, &groups), save);
            emit("fig8", report::fig8_report(s, &model_names, &groups), save);
            emit(
                "headline",
                report::headline_report(s, &model_names)?,
                save,
            );
        }
        other => bail!("unknown figure `{other}`"),
    }
    if !saved.is_empty() {
        out.push_str(&format!("saved: {}\n", saved.join(", ")));
    }
    Ok(out)
}

/// `codr simulate --model m [--arch a]` — per-layer stats on one design.
pub fn simulate(args: &Args) -> Result<String> {
    let name = args.get("model").context("simulate: --model required")?;
    let model = crate::models::parse_model(name)?;
    let arch = args.arch()?;
    let unique = args
        .get("unique")
        .map(|u| u.parse::<u32>().context("--unique"))
        .transpose()?;
    let density = args
        .get("density")
        .map(|d| d.parse::<f64>().context("--density"))
        .transpose()?;
    let wl = Workload::generate(&model, unique, density, args.seed()?);
    let acc = arch.build();
    let res = simulate_model(acc.as_ref(), &wl, "cli");

    let headers = vec![
        "layer", "weights", "b/w", "SRAM acc", "RF acc", "mults", "adds", "cycles", "energy µJ",
    ];
    let mut rows: Vec<Vec<String>> = res
        .layers
        .iter()
        .map(|l| {
            vec![
                l.layer.clone(),
                l.compression.num_weights.to_string(),
                format!("{:.2}", l.compression.bits_per_weight()),
                l.mem.sram_accesses().to_string(),
                l.mem.rf_accesses().to_string(),
                l.alu.mults().to_string(),
                l.alu.adds.to_string(),
                l.cycles.to_string(),
                format!("{:.1}", l.energy.total_uj()),
            ]
        })
        .collect();
    let c = res.compression();
    rows.push(vec![
        "TOTAL".into(),
        c.num_weights.to_string(),
        format!("{:.2}", c.bits_per_weight()),
        res.mem().sram_accesses().to_string(),
        res.mem().rf_accesses().to_string(),
        res.alu().mults().to_string(),
        res.alu().adds.to_string(),
        res.cycles().to_string(),
        format!("{:.1}", res.energy().total_uj()),
    ]);
    Ok(report::ascii_table(
        &format!("{} on {} (seed {})", model.name, arch.name(), args.seed()?),
        &headers,
        &rows,
    ))
}

/// `codr map --model m [--layer L]` — search one layer's mapping space
/// and print the Pareto front over (SRAM accesses, energy, utilization).
/// With `--addr`, submits a `map` job to a running server and streams it;
/// otherwise the search runs locally through the result store.
pub fn map(args: &Args) -> Result<String> {
    let name = args.get("model").context("map: --model required")?;
    if args.get("addr").is_some() {
        return map_remote(args, name);
    }
    let model = crate::models::parse_model(name)?;
    let group = args.single_group()?;
    let seed = args.seed()?;
    let cfg = crate::mapping::search::SearchConfig {
        max_candidates: args.max_candidates()?,
        quick: args.flag("quick"),
    };
    // A broken store degrades to an uncached search, like the figures.
    let store = if args.flag("fresh") {
        None
    } else {
        match ResultStore::open(args.store_dir()) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("warn: result store unavailable ({e:#}); searching uncached");
                None
            }
        }
    };
    let (unique, density) = group.knobs();
    let wl = Workload::generate(&model, unique, density, seed);
    let layer = args.get("layer");
    let Some((spec, w)) = wl
        .conv_layers()
        .find(|(s, _)| layer.map(|n| s.name == n).unwrap_or(true))
    else {
        match layer {
            Some(n) => bail!("model {name} has no conv layer named `{n}`"),
            None => bail!("model {name} has no conv layers"),
        }
    };
    let report = crate::mapping::search::search_layer(
        &crate::codr::Codr::default(),
        model.name,
        &group,
        seed,
        spec,
        w,
        &cfg,
        store.as_ref(),
        None,
    );
    let j = report.to_json();
    if args.flag("json") {
        Ok(j.to_string())
    } else {
        render_map_report(model.name, &group.label(), seed, &j)
    }
}

/// `codr map --addr`: submit the `map` verb, stream candidate progress
/// to stderr, render the front from the terminal `end` event. Output is
/// identical to the local path — both render the same report JSON.
fn map_remote(args: &Args, name: &str) -> Result<String> {
    let addr = args.addr();
    let group = args.single_group()?;
    let mut fields = vec![
        ("verb".into(), Json::str("map")),
        ("model".into(), Json::str(name)),
        ("group".into(), Json::str(group.label())),
        ("seed".into(), Json::u64(args.seed()?)),
        ("max_candidates".into(), Json::usize(args.max_candidates()?)),
    ];
    if let Some(l) = args.get("layer") {
        fields.push(("layer".into(), Json::str(l)));
    }
    if args.flag("quick") {
        fields.push(("quick".into(), Json::Bool(true)));
    }
    let retry = retry_policy(args)?;
    // `queued-full` refusals retry under the same budget as transport
    // errors — a full admission queue is a transient condition.
    let resp = proto::request_admitted(addr, &Json::Obj(fields), &retry)?;
    expect_ok(&resp)?;
    let job = resp.field("job")?.as_u64()?;
    let end = proto::watch_retry(addr, job, &retry, |ev| {
        if matches!(ev.get("event").map(|e| e.as_str()), Some(Ok("point"))) {
            let num = |k: &str| ev.get(k).and_then(|v| v.as_u64().ok()).unwrap_or(0);
            let tile = ev.get("group").and_then(|v| v.as_str().ok()).unwrap_or("?");
            eprintln!("[{}/{}] {tile}", num("done"), num("total"));
        }
    })?;
    if let Some(err) = end.get("error") {
        bail!("map job {job} failed: {}", err.as_str().unwrap_or("?"));
    }
    let map = end.field("map")?;
    if args.flag("json") {
        Ok(map.to_string())
    } else {
        render_map_report(name, &group.label(), args.seed()?, map)
    }
}

/// Render a map search report (the `SearchReport::to_json` shape) as the
/// human table plus the summary lines the CI smoke greps for.
fn render_map_report(model: &str, group: &str, seed: u64, j: &Json) -> Result<String> {
    let layer = j.field("layer")?.as_str()?;
    let front = j.field("front")?.as_arr()?;
    let headers = vec![
        "mapping",
        "SRAM acc",
        "energy µJ",
        "util",
        "cycles",
        "in-mc",
        "in-pass",
        "w-pass",
        "reduce",
    ];
    let rows: Vec<Vec<String>> = front
        .iter()
        .map(|c| -> Result<Vec<String>> {
            let reuse = c.field("reuse")?;
            Ok(vec![
                c.field("tile")?.as_str()?.to_string(),
                c.field("sram_accesses")?.as_u64()?.to_string(),
                format!("{:.2}", c.field("energy_uj")?.as_f64()?),
                format!("{:.3}", c.field("utilization")?.as_f64()?),
                c.field("cycles")?.as_u64()?.to_string(),
                format!("{:.0}", reuse.field("input_spatial_multicast")?.as_f64()?),
                format!("{:.0}", reuse.field("input_temporal_reuse")?.as_f64()?),
                format!("{:.0}", reuse.field("weight_temporal_reuse")?.as_f64()?),
                format!("{:.0}", reuse.field("output_temporal_reduction")?.as_f64()?),
            ])
        })
        .collect::<Result<_>>()?;
    let mut out = report::ascii_table(
        &format!("mapping Pareto front — {model}/{layer} [{group}] (seed {seed})"),
        &headers,
        &rows,
    );
    out.push_str(&format!(
        "\nfront: {} mappings ({} evaluated, {} illegal, {} dropped, {} cache hits)\n",
        front.len(),
        j.field("evaluated")?.as_usize()?,
        j.field("illegal")?.as_usize()?,
        j.field("dropped")?.as_usize()?,
        j.field("cache_hits")?.as_usize()?,
    ));
    out.push_str(if j.field("baseline_in_front")?.as_bool()? {
        "baseline: in front\n"
    } else {
        "baseline: dominated by front\n"
    });
    if let Some(best) = front.first() {
        out.push_str(&format!("best: {}\n", best.field("mapping")?.as_str()?));
    }
    Ok(out)
}

/// `codr compress --model m` — customized-RLE compression per layer.
pub fn compress(args: &Args) -> Result<String> {
    let name = args.get("model").context("compress: --model required")?;
    let model = crate::models::parse_model(name)?;
    let wl = Workload::generate(&model, None, None, args.seed()?);
    let cfg = crate::arch::TileConfig::codr();

    let headers = vec![
        "layer", "weights", "density", "uniq", "k", "r", "j", "Δ%", "cnt%", "idx%", "hdr%",
        "bits/w", "rate",
    ];
    let mut rows = Vec::new();
    let mut total = crate::rle::CompressionStats::default();
    for (spec, w) in wl.conv_layers() {
        let tiled = crate::reuse::transform_layer(spec, w, cfg.t_n, cfg.t_m);
        let vs: Vec<crate::reuse::UcrVector> =
            tiled.iter().flat_map(|(_, v)| v.iter().cloned()).collect();
        let enc = crate::rle::encode_layer(
            &vs,
            crate::rle::CoderSpec::new(cfg.t_m * spec.r_k * spec.r_k),
        );
        let st = enc.stats(spec.num_weights());
        total.add(&st);
        let share = |x: usize| format!("{:.0}%", 100.0 * x as f64 / st.encoded_bits as f64);
        rows.push(vec![
            spec.name.clone(),
            spec.num_weights().to_string(),
            format!("{:.2}", crate::quant::density(w.data())),
            crate::quant::unique_nonzero(w.data()).to_string(),
            enc.params.delta_bits.to_string(),
            enc.params.count_bits.to_string(),
            enc.params.index_bits.to_string(),
            share(st.delta_bits),
            share(st.count_bits),
            share(st.index_bits),
            share(st.header_bits),
            format!("{:.2}", st.bits_per_weight()),
            format!("{:.2}x", st.rate()),
        ]);
    }
    rows.push(vec![
        "TOTAL".into(),
        total.num_weights.to_string(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.2}", total.bits_per_weight()),
        format!("{:.2}x", total.rate()),
    ]);
    Ok(report::ascii_table(
        &format!("customized RLE: {} (seed {})", model.name, args.seed()?),
        &headers,
        &rows,
    ))
}

/// `codr golden` — run every artifact (per-layer convs and the end-to-end
/// tiny CNN) through the XLA golden model and compare against the CoDR
/// compressed datapath, bit for bit. Requires the `pjrt` feature.
#[cfg(feature = "pjrt")]
pub fn golden(args: &Args) -> Result<String> {
    let dir = std::path::Path::new(args.get("artifacts").unwrap_or("artifacts"));
    crate::runtime::golden::golden_report(dir, args.seed()?)
}

#[cfg(not(feature = "pjrt"))]
pub fn golden(_args: &Args) -> Result<String> {
    bail!(
        "`codr golden` needs the PJRT runtime — rebuild with \
         `--features pjrt` (requires the vendored `xla` crate; see ROADMAP.md)"
    )
}

/// `codr serve` — run the persistent sweep service (blocks until a
/// `shutdown` request, which drains in-flight jobs for up to
/// `--drain-secs` before exiting). `--store-cap-mb` bounds the store on
/// disk (oldest packs evicted first); the vector memo is restored from /
/// snapshotted to `<store>/memo.snapshot` across restarts.
pub fn serve(args: &Args) -> Result<String> {
    let store_dir = args.store_dir();
    let cap = args.store_cap_mb()?;
    let store = ResultStore::open_capped(&store_dir, cap.map(|mb| mb << 20))?;
    let mut server = Server::bind_with(args.addr(), store)?;
    server.set_drain_secs(args.drain_secs()?);
    server.set_conn_timeout_secs(args.conn_timeout_secs()?);
    server.set_max_queued(args.max_queued()?);
    let mut ring_note = String::new();
    if let Some(spec) = args.ring_spec() {
        // Both the advertised `--addr` and the bound socket address count
        // as "self" so `--addr host:0` still matches a ring entry that
        // names the advertised form.
        let self_addrs = [args.addr().to_string(), server.local_addr()?.to_string()];
        let ring = crate::serve::ring::Ring::parse(&spec, &self_addrs)?;
        ring_note = format!(
            ", ring {}/{} [{}]",
            ring.self_idx() + 1,
            ring.nodes().len(),
            ring.nodes().join(",")
        );
        server.set_ring(std::sync::Arc::new(crate::serve::ring::RingState::new(ring)));
    }
    // Announce before blocking so scripts can wait for readiness.
    let cap_note = match cap {
        Some(mb) => format!(", cap {mb} MiB"),
        None => String::new(),
    };
    println!(
        "codr serve: listening on {} (store: {}{cap_note}{ring_note})",
        server.local_addr()?,
        store_dir.display()
    );
    server.run()?;
    Ok("codr serve: shut down".to_string())
}

/// Build the grid fields shared by `submit` and `warm` requests.
fn grid_fields(args: &Args) -> Result<Vec<(String, Json)>> {
    // Validate locally so typos fail client-side with a real error.
    let models = args.models()?;
    let groups = args.groups()?;
    let mut fields = vec![
        (
            "models".into(),
            Json::str(
                models
                    .iter()
                    .map(|m| m.name)
                    .collect::<Vec<_>>()
                    .join(","),
            ),
        ),
        (
            "groups".into(),
            Json::str(
                groups
                    .iter()
                    .map(|g| g.label())
                    .collect::<Vec<_>>()
                    .join(","),
            ),
        ),
        ("seed".into(), Json::u64(args.seed()?)),
    ];
    if let Some(archs) = args.get("archs") {
        Arch::parse_list(archs)?;
        fields.push(("archs".into(), Json::str(archs)));
    }
    Ok(fields)
}

fn expect_ok(resp: &Json) -> Result<()> {
    if matches!(resp.get("ok").and_then(|o| o.as_bool().ok()), Some(true)) {
        Ok(())
    } else {
        let err = resp
            .get("error")
            .and_then(|e| e.as_str().ok().map(|s| s.to_string()))
            .unwrap_or_else(|| resp.to_string());
        bail!("server error: {err}")
    }
}

fn render_stats(stats: &SweepStats) -> String {
    let memo = match stats.memo_hit_rate() {
        Some(rate) => format!(
            "{:.0}% memo hits ({} L1 / {} L2, {} lock waits)",
            rate * 100.0,
            stats.l1_hits,
            stats.l2_hits,
            stats.lock_waits
        ),
        None => "no memo lookups".to_string(),
    };
    // `failed` only appears when nonzero, so fully-successful output is
    // byte-identical to earlier releases (scripts grep these lines).
    let failed = if stats.failed > 0 {
        format!("{} FAILED, ", stats.failed)
    } else {
        String::new()
    };
    format!(
        "{} points — {} cache hits, {} computed, {} deduped, {} corrupt, {failed}\
         {} layers simulated, {} ({} ms)",
        stats.requested,
        stats.cache_hits,
        stats.computed,
        stats.deduped,
        stats.corrupt,
        stats.simulated_layers,
        memo,
        stats.wall_ms
    )
}

/// `codr watch --job N` — attach to a submitted job and stream its
/// per-point progress (events to stderr, final stats as the result).
pub fn watch(args: &Args) -> Result<String> {
    watch_to_end(args.addr(), args.job()?, &retry_policy(args)?)
}

/// `codr ring` — query a ring-mode server: membership, per-peer health
/// and forward/repair gauges; with `--model` (plus `--group`/`--seed`),
/// also resolve which node owns that pack.
pub fn ring(args: &Args) -> Result<String> {
    let addr = args.addr();
    let mut fields = vec![("verb".into(), Json::str("ring"))];
    if let Some(model) = args.get("model") {
        fields.push(("model".into(), Json::str(model)));
        fields.push(("group".into(), Json::str(args.get("group").unwrap_or("Orig"))));
        fields.push(("seed".into(), Json::u64(args.seed()?)));
    }
    let resp = proto::request_retry(addr, &Json::Obj(fields), &retry_policy(args)?)?;
    expect_ok(&resp)?;
    let ring = resp.field("ring")?;
    let s = |j: &Json, k: &str| -> String {
        j.get(k)
            .and_then(|v| v.as_str().ok())
            .unwrap_or("?")
            .to_string()
    };
    let n = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_u64().ok()).unwrap_or(0);
    let nodes = match ring.field("nodes")?.as_arr() {
        Ok(arr) => arr
            .iter()
            .filter_map(|v| v.as_str().ok())
            .collect::<Vec<_>>()
            .join(","),
        Err(_) => "?".to_string(),
    };
    let mut out = format!(
        "ring via {addr}: self {}, nodes [{nodes}], {} forwards, {} repairs",
        s(ring, "self"),
        n(ring, "forwards"),
        n(ring, "repairs"),
    );
    if let Ok(peers) = ring.field("peers").and_then(|p| p.as_arr()) {
        for p in peers {
            out.push_str(&format!(
                "\n  peer {:<21} {:<7} forwards {} (errors {}), repairs {}, probe p99 {} ms",
                s(p, "addr"),
                s(p, "state"),
                n(p, "forwards"),
                n(p, "forward_errors"),
                n(p, "repairs"),
                p.get("probe_p99_ms").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
            ));
        }
    }
    if let Some(pack) = resp.get("pack") {
        out.push_str(&format!(
            "\n  pack {} -> owner {}{}",
            s(pack, "stem"),
            s(pack, "owner"),
            if pack.get("owned").and_then(|o| o.as_bool().ok()) == Some(true) {
                " (the node answering)"
            } else {
                ""
            }
        ));
    }
    Ok(out)
}

/// The client retry policy from `--retries` (0 = fail fast).
fn retry_policy(args: &Args) -> Result<proto::Retry> {
    Ok(proto::Retry::attempts(args.retries()?))
}

/// Attach to `job` on `addr`, narrate `point` events to stderr, and
/// render the terminal `end` event (shared by `codr watch` and
/// `codr submit --watch`). A dropped stream reconnects under `retry`;
/// the server's replay plus client-side dedup keeps the narration
/// exactly-once.
fn watch_to_end(addr: &str, job: u64, retry: &proto::Retry) -> Result<String> {
    let end = proto::watch_retry(addr, job, retry, |ev| {
        if matches!(ev.get("event").map(|e| e.as_str()), Some(Ok("point"))) {
            let num = |k: &str| ev.get(k).and_then(|v| v.as_u64().ok()).unwrap_or(0);
            let txt = |k: &str| {
                ev.get(k)
                    .and_then(|v| v.as_str().ok())
                    .unwrap_or("?")
                    .to_string()
            };
            let hit = matches!(ev.get("cache_hit").and_then(|v| v.as_bool().ok()), Some(true));
            let note = match ev.get("error").and_then(|v| v.as_str().ok()) {
                Some(err) => format!(" FAILED: {err}"),
                None if hit => " (cache hit)".to_string(),
                None => String::new(),
            };
            eprintln!(
                "job {job}: {}/{} {} {} {}{note}",
                num("done"),
                num("total"),
                txt("model"),
                txt("group"),
                txt("arch"),
            );
        }
    })?;
    if let Some(err) = end.get("error").and_then(|e| e.as_str().ok()) {
        bail!("job {job} failed: {err}");
    }
    let state = end
        .get("state")
        .and_then(|s| s.as_str().ok())
        .unwrap_or("done")
        .to_string();
    let stats = proto::stats_from_json(end.field("stats")?)?;
    Ok(format!("job {job} {state}: {}", render_stats(&stats)))
}

/// `codr submit` — send a grid to a running `codr serve`; then stream
/// progress (`--watch`), poll until done (`--wait`), or return the job
/// id immediately.
pub fn submit(args: &Args) -> Result<String> {
    let addr = args.addr();
    let retry = retry_policy(args)?;
    let mut fields = vec![("verb".into(), Json::str("submit"))];
    fields.extend(grid_fields(args)?);
    // Admission-aware: a `queued-full` refusal backs off and retries
    // under `--retries` instead of being treated as success or a hard
    // failure with budget remaining.
    let resp = proto::request_admitted(addr, &Json::Obj(fields), &retry)?;
    expect_ok(&resp)?;
    let job = resp.field("job")?.as_u64()?;
    if resp.get("state").and_then(|s| s.as_str().ok()) == Some("done-degraded") {
        // The pack owner was down, so the node we dialed computed the
        // grid itself and journaled the results for anti-entropy repair.
        let stats = proto::stats_from_json(resp.field("stats")?)?;
        let owner = resp
            .get("owner")
            .and_then(|o| o.as_str().ok())
            .unwrap_or("unknown");
        return Ok(format!(
            "job {job} done-degraded: {} (owner {owner} down; results held on {addr} \
             until repair)",
            render_stats(&stats)
        ));
    }
    // A forwarded submit ran on the pack owner — poll/stream there, not
    // on the node we dialed (the job table lives with the owner).
    let poll_addr = match resp.get("owner").and_then(|o| o.as_str().ok()) {
        Some(owner) if resp.get("forwarded").is_some() => owner.to_string(),
        _ => addr.to_string(),
    };
    let addr = poll_addr.as_str();
    let points = resp.field("points")?.as_u64()?;
    if args.flag("watch") {
        return watch_to_end(addr, job, &retry);
    }
    if !args.flag("wait") {
        return Ok(format!(
            "submitted job {job} ({points} points) to {addr} — stream with \
             `codr watch --job {job}`, or poll with `codr submit --wait` / the status verb"
        ));
    }
    loop {
        std::thread::sleep(std::time::Duration::from_millis(100));
        let status = proto::request_retry(
            addr,
            &Json::Obj(vec![
                ("verb".into(), Json::str("status")),
                ("job".into(), Json::u64(job)),
            ]),
            &retry,
        )?;
        expect_ok(&status)?;
        match status.field("state")?.as_str()? {
            "running" => continue,
            state @ ("done" | "partial") => {
                let stats = proto::stats_from_json(status.field("stats")?)?;
                return Ok(format!("job {job} {state}: {}", render_stats(&stats)));
            }
            "failed" => {
                let err = status
                    .get("error")
                    .and_then(|e| e.as_str().ok())
                    .unwrap_or("unknown");
                bail!("job {job} failed: {err}");
            }
            "expired" => bail!(
                "job {job} finished but was pruned from the job table before this poll \
                 (its results are in the store)"
            ),
            other => bail!("job {job}: unexpected state `{other}`"),
        }
    }
}

/// `codr warm` — populate the result store for a grid, either through a
/// running server (`--addr` reachable) or locally against the on-disk
/// store.
pub fn warm(args: &Args) -> Result<String> {
    // Prefer a running server when one was explicitly named.
    if args.get("addr").is_some() {
        let mut fields = vec![("verb".into(), Json::str("warm"))];
        fields.extend(grid_fields(args)?);
        let resp =
            proto::request_admitted(args.addr(), &Json::Obj(fields), &retry_policy(args)?)?;
        expect_ok(&resp)?;
        let stats = proto::stats_from_json(resp.field("stats")?)?;
        return Ok(format!("warm (via {}): {}", args.addr(), render_stats(&stats)));
    }
    let models = args.models()?;
    let groups = args.groups()?;
    let archs = match args.get("archs") {
        Some(spec) => Arch::parse_list(spec)?,
        None => Arch::all().to_vec(),
    };
    let store = ResultStore::open(args.store_dir())?;
    // Local warms bracket the sweep with the persistent vector memo, so
    // repeated `codr warm` processes share transforms the way a
    // long-running `codr serve` does. Best-effort both ways: a missing
    // or damaged snapshot is just a cold memo.
    let snapshot = crate::serve::memo_snapshot_path(store.dir());
    if let Some(p) = &snapshot {
        if let Ok(n) = crate::reuse::memo::global().load_snapshot(p) {
            if n > 0 {
                eprintln!("memo: restored {n} vectors from {}", p.display());
            }
        }
    }
    let results = run_sweep_with(&models, &groups, &archs, args.seed()?, Some(&store));
    if let Some(p) = &snapshot {
        let _ = crate::reuse::memo::global().save_snapshot_if_warm(p);
    }
    Ok(format!(
        "warm ({}): {}",
        store.dir().display(),
        render_stats(&results.stats)
    ))
}

/// `codr bench` — time the simulation hot path on the model zoo and
/// write a machine-readable snapshot (`BENCH_hotpath.json` by default;
/// `--out` overrides, `--quick` shrinks the grid for CI smoke runs).
/// Snapshot format v2: each optimized pass reports the two-level memo
/// breakdown (L1/L2 hits, collision verifies, double computes, lock
/// waits) and per-phase wall times (extract / transform / price).
///
/// Three passes over the same per-layer task list establish the perf
/// trajectory:
///
/// 1. **reference** — the seed pipeline (full transform + bitstream
///    emission per layer), the pre-optimization baseline;
/// 2. **optimized cold** — the memoized hot path with a flushed vector
///    memo (what a fresh process pays);
/// 3. **optimized warm** — the same grid again with the memo populated
///    (what a long-running `codr serve` pays).
///
/// All passes fan out per (arch, layer) over the worker pool, so the
/// comparison isolates the hot-path rework from the scheduling rework.
pub fn bench(args: &Args) -> Result<String> {
    use crate::baselines::{scnn, ucnn, Scnn, Ucnn};
    use crate::codr::{dataflow, Codr};
    use crate::coordinator::pool;
    use crate::models::SweepGroup;
    use crate::reuse::memo;
    use crate::sim::Accelerator;
    use crate::util::bench::{phases, Bencher, PhaseSnapshot};
    use std::time::{Duration, Instant};

    let quick = args.flag("quick");
    let models = if quick && args.get("models").is_none() {
        vec![crate::models::tiny_cnn()]
    } else {
        args.models()?
    };
    let groups = if quick && args.get("groups").is_none() {
        vec![SweepGroup::Original, SweepGroup::Density(50)]
    } else {
        args.groups()?
    };
    let seed = args.seed()?;
    let archs = Arch::all();

    // Workload synthesis is excluded from every timing — the hot path
    // under test is the simulation, not the weight synthesis.
    let mut points = Vec::new();
    for model in &models {
        for &group in &groups {
            points.push((model.clone(), group));
        }
    }
    let workloads: Vec<Workload> = pool::parallel_map(&points, |(model, group)| {
        let (unique, density) = group.knobs();
        Workload::generate(model, unique, density, seed)
    });
    let mut tasks: Vec<(usize, usize, usize)> = Vec::new();
    for (pi, wl) in workloads.iter().enumerate() {
        let n_layers = wl.conv_layers().count();
        for ai in 0..archs.len() {
            for li in 0..n_layers {
                tasks.push((pi, ai, li));
            }
        }
    }
    let n_layer_sims = tasks.len();
    let layers_per_sec = |ms: u64| {
        if ms == 0 {
            n_layer_sims as f64 * 1000.0
        } else {
            n_layer_sims as f64 * 1000.0 / ms as f64
        }
    };

    // Pass 1: the seed pipeline.
    let t_ref = Instant::now();
    let reference_cycles: u64 = pool::parallel_map(&tasks, |&(pi, ai, li)| {
        let (spec, w) = workloads[pi].conv_layers().nth(li).expect("bench layer");
        match archs[ai] {
            Arch::Codr => dataflow::simulate_layer_reference(&Codr::default(), spec, w),
            Arch::Ucnn => ucnn::simulate_layer_reference(&Ucnn::default(), spec, w),
            Arch::Scnn => scnn::simulate_layer_reference(&Scnn::default(), spec, w),
        }
        .cycles
    })
    .iter()
    .sum();
    let ref_ms = t_ref.elapsed().as_millis() as u64;

    let optimized_pass = || -> (u64, u64, memo::MemoCounters, PhaseSnapshot) {
        let memo0 = memo::global().breakdown();
        let phases0 = phases().snapshot();
        let t = Instant::now();
        let cycles: u64 = pool::parallel_map(&tasks, |&(pi, ai, li)| {
            let acc = archs[ai].build();
            let (spec, w) = workloads[pi].conv_layers().nth(li).expect("bench layer");
            acc.simulate_layer(spec, w).cycles
        })
        .iter()
        .sum();
        let ms = t.elapsed().as_millis() as u64;
        (
            ms,
            cycles,
            memo::global().breakdown().since(&memo0),
            phases().snapshot().since(&phases0),
        )
    };

    // Pass 2: optimized, memo cold. Pass 3: optimized, memo warm.
    memo::global().flush();
    let (cold_ms, cold_cycles, cold_memo, cold_phases) = optimized_pass();
    let (warm_ms, warm_cycles, warm_memo, warm_phases) = optimized_pass();
    if cold_cycles != reference_cycles || warm_cycles != reference_cycles {
        bail!(
            "hot path diverged from reference (cycles {cold_cycles}/{warm_cycles} \
             vs {reference_cycles}) — run the invariance tests"
        );
    }
    // Counter conservation: every lookup resolves at exactly one level,
    // so a standalone bench run (the pool joins between snapshots) must
    // see `lookups == l1 + l2 + misses` per pass — the CI quick-bench
    // smoke asserts it on the emitted JSON. In-process we only warn:
    // concurrent users of the global memo (e.g. parallel unit tests)
    // can legitimately skew a window's deltas by their in-flight
    // lookups.
    for (pass, m) in [("cold", &cold_memo), ("warm", &warm_memo)] {
        if m.lookups != m.l1_hits + m.l2_hits + m.misses {
            eprintln!(
                "warn: memo counter deltas skewed in the {pass} pass \
                 (concurrent memo users?): {m:?}"
            );
        }
    }

    // Micro benches on the largest conv layer of the first workload.
    let mut b = Bencher::with(3, 15, Duration::from_secs(2), 1);
    let mut micro = Vec::new();
    if let Some((spec, w)) = workloads
        .first()
        .and_then(|wl| wl.conv_layers().max_by_key(|(s, _)| s.num_weights()))
    {
        let design = Codr::default();
        let s1 = b
            .bench(&format!("codr_layer_reference/{}", spec.name), || {
                dataflow::simulate_layer_reference(&design, spec, w).cycles
            })
            .clone();
        let s2 = b
            .bench(&format!("codr_layer_memoized/{}", spec.name), || {
                dataflow::simulate_layer(&design, spec, w).cycles
            })
            .clone();
        micro.push(s1);
        micro.push(s2);
    }

    // Bench snapshot v2: each optimized pass carries the two-level memo
    // breakdown and the per-phase wall times (extract ⊃ transform, plus
    // price), so a regression is attributable from the JSON alone.
    let pass_json = |ms: u64, m: &memo::MemoCounters, ph: &PhaseSnapshot| {
        let total = m.hits() + m.misses;
        let rate = if total == 0 {
            Json::Null
        } else {
            Json::f64(m.hits() as f64 / total as f64)
        };
        let l1_rate = if m.lookups == 0 {
            Json::Null
        } else {
            Json::f64(m.l1_hits as f64 / m.lookups as f64)
        };
        Json::Obj(vec![
            ("wall_ms".into(), Json::u64(ms)),
            ("layers_per_sec".into(), Json::f64(layers_per_sec(ms))),
            // Flat totals kept from v1 for easy diffing across versions.
            ("memo_hits".into(), Json::u64(m.hits())),
            ("memo_misses".into(), Json::u64(m.misses)),
            ("memo_hit_rate".into(), rate.clone()),
            (
                "memo".into(),
                Json::Obj(vec![
                    ("lookups".into(), Json::u64(m.lookups)),
                    ("l1_hits".into(), Json::u64(m.l1_hits)),
                    ("l2_hits".into(), Json::u64(m.l2_hits)),
                    ("misses".into(), Json::u64(m.misses)),
                    ("l1_hit_rate".into(), l1_rate),
                    ("hit_rate".into(), rate),
                    ("collision_verifies".into(), Json::u64(m.collision_verifies)),
                    ("double_computes".into(), Json::u64(m.double_computes)),
                    ("lock_waits".into(), Json::u64(m.lock_waits)),
                    ("evictions".into(), Json::u64(m.evictions)),
                ]),
            ),
            (
                "phases".into(),
                Json::Obj(vec![
                    (
                        "extract_ms".into(),
                        Json::f64(ph.extract_ns as f64 / 1e6),
                    ),
                    (
                        "transform_ms".into(),
                        Json::f64(ph.transform_ns as f64 / 1e6),
                    ),
                    ("price_ms".into(), Json::f64(ph.price_ns as f64 / 1e6)),
                ]),
            ),
        ])
    };
    let ratio = |num: u64, den: u64| {
        if den == 0 {
            Json::Null
        } else {
            Json::f64(num as f64 / den as f64)
        }
    };
    let json = Json::Obj(vec![
        ("bench".into(), Json::str("hotpath")),
        ("version".into(), Json::u64(2)),
        (
            "note".into(),
            Json::str(
                "machine-dependent snapshot from `codr bench` — regenerate \
                 locally for comparable numbers",
            ),
        ),
        (
            "grid".into(),
            Json::Obj(vec![
                (
                    "models".into(),
                    Json::str(models.iter().map(|m| m.name).collect::<Vec<_>>().join(",")),
                ),
                (
                    "groups".into(),
                    Json::str(
                        groups
                            .iter()
                            .map(|g| g.label())
                            .collect::<Vec<_>>()
                            .join(","),
                    ),
                ),
                ("archs".into(), Json::str("CoDR,UCNN,SCNN")),
                ("seed".into(), Json::u64(seed)),
                ("quick".into(), Json::Bool(quick)),
                ("threads".into(), Json::usize(pool::default_threads())),
                ("layer_sims".into(), Json::usize(n_layer_sims)),
            ]),
        ),
        (
            "reference".into(),
            Json::Obj(vec![
                ("wall_ms".into(), Json::u64(ref_ms)),
                ("layers_per_sec".into(), Json::f64(layers_per_sec(ref_ms))),
            ]),
        ),
        (
            "optimized_cold".into(),
            pass_json(cold_ms, &cold_memo, &cold_phases),
        ),
        (
            "optimized_warm".into(),
            pass_json(warm_ms, &warm_memo, &warm_phases),
        ),
        ("speedup_cold".into(), ratio(ref_ms, cold_ms)),
        ("speedup_warm".into(), ratio(ref_ms, warm_ms)),
        ("arena".into(), {
            let (entries, bytes, tombstoned) = memo::global().arena_stats();
            Json::Obj(vec![
                ("entries".into(), Json::usize(entries)),
                ("bytes".into(), Json::u64(bytes)),
                ("tombstoned_bytes".into(), Json::u64(tombstoned)),
            ])
        }),
        (
            "micro".into(),
            Json::Arr(
                micro
                    .iter()
                    .map(|s| {
                        Json::Obj(vec![
                            ("name".into(), Json::str(s.name.clone())),
                            ("median_ns".into(), Json::u64(s.median().as_nanos() as u64)),
                            ("mean_ns".into(), Json::u64(s.mean().as_nanos() as u64)),
                            ("min_ns".into(), Json::u64(s.min().as_nanos() as u64)),
                            ("noise".into(), Json::f64(s.noise())),
                            ("samples".into(), Json::usize(s.samples.len())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);

    let out_path = args.get("out").unwrap_or("BENCH_hotpath.json");
    std::fs::write(out_path, json.to_pretty_string() + "\n")
        .with_context(|| format!("writing {out_path}"))?;

    let speedup = |den: u64| {
        if den == 0 {
            f64::INFINITY
        } else {
            ref_ms as f64 / den as f64
        }
    };
    let (arena_entries, arena_bytes, arena_tombstoned) = memo::global().arena_stats();
    Ok(format!(
        "hot path over {} layer sims ({} threads):\n\
         \u{20} reference       {:>8} ms  ({:.1} layers/s)\n\
         \u{20} optimized cold  {:>8} ms  ({:.1} layers/s, {:.1}x, memo {}/{} hits, {} L1)\n\
         \u{20} optimized warm  {:>8} ms  ({:.1} layers/s, {:.1}x, memo {}/{} hits, {} L1)\n\
         \u{20} memo arena      {} entries, {} bytes live, {} bytes tombstoned\n\
         wrote {}",
        n_layer_sims,
        pool::default_threads(),
        ref_ms,
        layers_per_sec(ref_ms),
        cold_ms,
        layers_per_sec(cold_ms),
        speedup(cold_ms),
        cold_memo.hits(),
        cold_memo.lookups,
        cold_memo.l1_hits,
        warm_ms,
        layers_per_sec(warm_ms),
        speedup(warm_ms),
        warm_memo.hits(),
        warm_memo.lookups,
        warm_memo.l1_hits,
        arena_entries,
        arena_bytes,
        arena_tombstoned,
        out_path
    ))
}

/// `codr info` — configurations and model zoo.
pub fn info() -> String {
    let mut out = report::table1_report();
    out.push('\n');
    let headers = vec!["model", "conv layers", "conv weights", "MACs"];
    let rows: Vec<Vec<String>> = crate::models::all_models()
        .iter()
        .map(|m| {
            vec![
                m.name.to_string(),
                m.conv_layers().count().to_string(),
                m.conv_layers()
                    .map(|l| l.num_weights())
                    .sum::<usize>()
                    .to_string(),
                format!(
                    "{:.2}G",
                    m.conv_layers().map(|l| l.macs()).sum::<u64>() as f64 / 1e9
                ),
            ]
        })
        .collect();
    out.push_str(&report::ascii_table("model zoo", &headers, &rows));
    out
}

/// `codr analyze` — static invariant checks over `rust/src`.
///
/// `--print-env-table` prints the README env-var block (markers
/// included) instead of scanning. Findings exit 2 via [`super::Outcome`]
/// rather than `Err`: the report rendered fine, the nonzero code is the
/// verdict, and the usage dump must not fire.
pub fn analyze(args: &Args) -> Result<super::Outcome> {
    use crate::analysis::{self, env_registry};
    if args.flag("print-env-table") {
        let text = format!(
            "{}\n{}{}",
            env_registry::README_BEGIN,
            env_registry::render_table(),
            env_registry::README_END
        );
        return Ok(super::Outcome { text, code: 0 });
    }
    let root = match args.get("src") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => analysis::default_src_root(),
    };
    let report = analysis::analyze_tree(&root)
        .with_context(|| format!("analyze: scanning {}", root.display()))?;
    let text = if args.flag("json") {
        report.to_json()
    } else {
        report.render()
    };
    let code = if report.is_clean() { 0 } else { 2 };
    Ok(super::Outcome { text, code })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn simulate_tiny_renders_totals() {
        let a = Args::parse(&sv(&["--model", "tiny", "--arch", "scnn"])).unwrap();
        let out = simulate(&a).unwrap();
        assert!(out.contains("TOTAL"));
        assert!(out.contains("SCNN"));
    }

    #[test]
    fn compress_tiny_shows_params() {
        let a = Args::parse(&sv(&["--model", "tiny"])).unwrap();
        let out = compress(&a).unwrap();
        assert!(out.contains("conv1") && out.contains("rate"));
    }

    #[test]
    fn simulate_requires_model() {
        assert!(simulate(&Args::parse(&[]).unwrap()).is_err());
    }

    #[test]
    fn figure_rejects_unknown() {
        let a = Args::parse(&[]).unwrap();
        assert!(figure("fig99", &a).is_err());
    }

    #[test]
    fn warm_then_figure_hits_cache_and_matches_fresh_output() {
        let dir = std::env::temp_dir().join(format!(
            "codr-cli-warm-test-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_string_lossy().to_string();
        let base = ["--models", "tiny", "--groups", "Orig", "--store", &dir_s];
        let warm_args = Args::parse(&sv(&base)).unwrap();
        let out = warm(&warm_args).unwrap();
        assert!(out.contains("0 cache hits"), "{out}");
        assert!(out.contains("3 computed"), "{out}");

        // Cached figure equals a fresh (storeless) run byte for byte.
        let cached = figure("headline", &warm_args).unwrap();
        let mut fresh_argv = base.to_vec();
        fresh_argv.push("--fresh");
        let fresh = figure("headline", &Args::parse(&sv(&fresh_argv)).unwrap()).unwrap();
        assert_eq!(cached, fresh);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_quick_writes_parseable_snapshot() {
        let out = std::env::temp_dir().join(format!(
            "codr-bench-test-{}.json",
            std::process::id()
        ));
        let out_s = out.to_string_lossy().to_string();
        let a = Args::parse(&sv(&[
            "--quick", "--models", "tiny", "--groups", "Orig", "--out", &out_s,
        ]))
        .unwrap();
        let summary = bench(&a).unwrap();
        assert!(summary.contains("optimized cold"), "{summary}");
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.field("bench").unwrap().as_str().unwrap(), "hotpath");
        assert_eq!(j.field("version").unwrap().as_u64().unwrap(), 2);
        assert!(j.get("speedup_cold").is_some());
        let warm = j.field("optimized_warm").unwrap();
        assert!(warm.get("memo_hits").is_some());
        // v2 structure: per-pass memo breakdown + phase wall times.
        // (Strict counter conservation is asserted by the CI smoke on a
        // standalone run — in-process, concurrently running tests that
        // share the global memo can skew a window's deltas.)
        for pass in ["optimized_cold", "optimized_warm"] {
            let memo = j.field(pass).unwrap().field("memo").unwrap();
            for k in [
                "lookups",
                "l1_hits",
                "l2_hits",
                "misses",
                "collision_verifies",
                "double_computes",
                "lock_waits",
                "evictions",
            ] {
                assert!(memo.field(k).unwrap().as_u64().is_ok(), "{pass} {k}");
            }
            let phases = j.field(pass).unwrap().field("phases").unwrap();
            for k in ["extract_ms", "transform_ms", "price_ms"] {
                assert!(phases.get(k).is_some(), "{pass} missing {k}");
            }
        }
        let arena = j.field("arena").unwrap();
        for k in ["entries", "bytes", "tombstoned_bytes"] {
            assert!(arena.field(k).unwrap().as_u64().is_ok(), "arena {k}");
        }
        assert!(summary.contains("memo arena"), "{summary}");
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn submit_without_server_fails_cleanly() {
        // Port 1 is never listening; the client must error, not hang.
        let a = Args::parse(&sv(&["--addr", "127.0.0.1:1", "--models", "tiny"])).unwrap();
        assert!(submit(&a).is_err());
    }

    #[test]
    fn watch_without_server_fails_cleanly() {
        let a = Args::parse(&sv(&["--addr", "127.0.0.1:1", "--job", "1"])).unwrap();
        assert!(watch(&a).is_err());
        // And --job is validated before any connection is attempted.
        let a = Args::parse(&sv(&["--addr", "127.0.0.1:1"])).unwrap();
        assert!(watch(&a).unwrap_err().to_string().contains("--job"));
    }
}
