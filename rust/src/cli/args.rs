//! Flag parsing for the CLI: `--key value` pairs plus boolean switches.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

#[derive(Clone, Debug, Default)]
pub struct Args {
    values: HashMap<String, String>,
    switches: Vec<String>,
}

const SWITCHES: &[&str] = &[
    "save",
    "functional",
    "verbose",
    "fresh",
    "wait",
    "watch",
    "quick",
    "json",
    "print-env-table",
];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            let Some(key) = tok.strip_prefix("--") else {
                bail!("unexpected argument `{tok}` (flags start with --)");
            };
            if SWITCHES.contains(&key) {
                a.switches.push(key.to_string());
                i += 1;
            } else {
                let val = argv
                    .get(i + 1)
                    .with_context(|| format!("--{key} needs a value"))?;
                a.values.insert(key.to_string(), val.clone());
                i += 2;
            }
        }
        Ok(a)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    pub fn seed(&self) -> Result<u64> {
        match self.get("seed") {
            None => Ok(42),
            Some(s) => s.parse().context("--seed must be an integer"),
        }
    }

    /// Comma-separated model list (default: the paper's three benchmarks).
    pub fn models(&self) -> Result<Vec<crate::models::Model>> {
        crate::models::parse_model_list(self.get("models").unwrap_or("alexnet,vgg16,googlenet"))
    }

    /// Sweep groups (default: all six paper groups).
    pub fn groups(&self) -> Result<Vec<crate::models::SweepGroup>> {
        match self.get("groups") {
            None => Ok(crate::models::SweepGroup::all()),
            Some(spec) => crate::models::parse_group_list(spec),
        }
    }

    pub fn arch(&self) -> Result<crate::coordinator::Arch> {
        crate::coordinator::Arch::parse(self.get("arch").unwrap_or("CoDR"))
    }

    /// Result-store directory (`--store`, then `$CODR_STORE`, then
    /// `results/store`).
    pub fn store_dir(&self) -> PathBuf {
        match self.get("store") {
            Some(dir) => PathBuf::from(dir),
            None => crate::serve::default_store_dir(),
        }
    }

    /// Serve/submit/warm address (`--addr`, default 127.0.0.1:7878).
    pub fn addr(&self) -> &str {
        self.get("addr").unwrap_or(crate::serve::DEFAULT_ADDR)
    }

    /// Multi-host ring spec for `codr serve` (`--ring`, then
    /// `$CODR_RING`; `None` = single-node). A comma-separated
    /// `host:port` list that must include this node's own `--addr`.
    pub fn ring_spec(&self) -> Option<String> {
        match self.get("ring") {
            Some(spec) => Some(spec.to_string()),
            None => crate::analysis::env_registry::var("CODR_RING").filter(|v| !v.is_empty()),
        }
    }

    /// Shutdown drain budget in seconds (`--drain-secs`, default 30).
    /// Zero is allowed and means "abandon in-flight work immediately".
    pub fn drain_secs(&self) -> Result<u64> {
        match self.get("drain-secs") {
            None => Ok(crate::serve::DEFAULT_DRAIN_SECS),
            Some(s) => s.parse().context("--drain-secs must be an integer"),
        }
    }

    /// Client retry budget (`--retries`, default 0 = fail fast). Each
    /// retry backs off exponentially with seeded jitter; `watch`
    /// reconnects replay the event history and dedup to exactly-once.
    pub fn retries(&self) -> Result<u32> {
        match self.get("retries") {
            None => Ok(0),
            Some(s) => s.parse().context("--retries must be an integer"),
        }
    }

    /// Per-connection socket timeout for `codr serve`
    /// (`--conn-timeout-secs`; 0 or unset = unbounded).
    pub fn conn_timeout_secs(&self) -> Result<u64> {
        match self.get("conn-timeout-secs") {
            None => Ok(0),
            Some(s) => s.parse().context("--conn-timeout-secs must be an integer"),
        }
    }

    /// Admission-queue bound for `codr serve` (`--max-queued`, default
    /// 64). Caps *waiting* tasks only; past the cap, `submit`/`warm`/`map`
    /// answer `state:"queued-full"` instead of queueing.
    pub fn max_queued(&self) -> Result<usize> {
        match self.get("max-queued") {
            None => Ok(crate::serve::server::DEFAULT_MAX_QUEUED),
            Some(s) => {
                let n: usize = s.parse().context("--max-queued must be an integer")?;
                if n == 0 {
                    bail!("--max-queued must be at least 1");
                }
                Ok(n)
            }
        }
    }

    /// Job id for `codr watch` (`--job`).
    pub fn job(&self) -> Result<u64> {
        self.get("job")
            .context("--job required (the id `codr submit` printed)")?
            .parse()
            .context("--job must be an integer job id")
    }

    /// Candidate cap for `codr map` (`--max-candidates`, default 512;
    /// must be at least 1 — the baseline mapping is always evaluated).
    pub fn max_candidates(&self) -> Result<usize> {
        match self.get("max-candidates") {
            None => Ok(512),
            Some(s) => {
                let n: usize = s.parse().context("--max-candidates must be an integer")?;
                if n == 0 {
                    bail!("--max-candidates must be at least 1");
                }
                Ok(n)
            }
        }
    }

    /// The single sweep group for `codr map` (`--group`, default Orig).
    pub fn single_group(&self) -> Result<crate::models::SweepGroup> {
        match self.get("group") {
            None => Ok(crate::models::SweepGroup::Original),
            Some(spec) => {
                let gs = crate::models::parse_group_list(spec)?;
                if gs.len() != 1 {
                    bail!("--group must name exactly one sweep group");
                }
                Ok(gs[0])
            }
        }
    }

    /// Result-store size cap in mebibytes (`--store-cap-mb`; `None` =
    /// unbounded). Zero is rejected — a cap that evicts every save is a
    /// configuration error, not a policy.
    pub fn store_cap_mb(&self) -> Result<Option<u64>> {
        match self.get("store-cap-mb") {
            None => Ok(None),
            Some(s) => {
                let mb: u64 = s.parse().context("--store-cap-mb must be an integer")?;
                if mb == 0 {
                    bail!("--store-cap-mb must be at least 1");
                }
                Ok(Some(mb))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SweepGroup;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_pairs_and_switches() {
        let a = Args::parse(&sv(&["--seed", "7", "--save", "--model", "vgg16"])).unwrap();
        assert_eq!(a.seed().unwrap(), 7);
        assert!(a.flag("save"));
        assert_eq!(a.get("model"), Some("vgg16"));
    }

    #[test]
    fn default_seed_and_models() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.seed().unwrap(), 42);
        assert_eq!(a.models().unwrap().len(), 3);
        assert_eq!(a.groups().unwrap().len(), 6);
    }

    #[test]
    fn group_spec_parsing() {
        let a = Args::parse(&sv(&["--groups", "U=16,Orig,D=50%"])).unwrap();
        assert_eq!(
            a.groups().unwrap(),
            vec![
                SweepGroup::Unique(16),
                SweepGroup::Original,
                SweepGroup::Density(50)
            ]
        );
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Args::parse(&sv(&["positional"])).is_err());
        assert!(Args::parse(&sv(&["--seed"])).is_err());
        let a = Args::parse(&sv(&["--groups", "X=9"])).unwrap();
        assert!(a.groups().is_err());
        let a = Args::parse(&sv(&["--arch", "tpu"])).unwrap();
        assert!(a.arch().is_err());
        let a = Args::parse(&sv(&["--models", "resnet"])).unwrap();
        assert!(a.models().is_err());
    }

    #[test]
    fn store_and_addr_defaults() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.addr(), "127.0.0.1:7878");
        let a = Args::parse(&sv(&["--store", "/tmp/s", "--addr", "127.0.0.1:9"])).unwrap();
        assert_eq!(a.store_dir(), PathBuf::from("/tmp/s"));
        assert_eq!(a.addr(), "127.0.0.1:9");
        assert!(Args::parse(&sv(&["--fresh", "--wait"])).is_ok());
    }

    #[test]
    fn drain_and_job_parsing() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.drain_secs().unwrap(), crate::serve::DEFAULT_DRAIN_SECS);
        assert!(a.job().is_err());
        let a = Args::parse(&sv(&["--drain-secs", "0", "--job", "7", "--watch"])).unwrap();
        assert_eq!(a.drain_secs().unwrap(), 0);
        assert_eq!(a.job().unwrap(), 7);
        assert!(a.flag("watch"));
        assert!(Args::parse(&sv(&["--drain-secs", "soon"]))
            .unwrap()
            .drain_secs()
            .is_err());
        assert!(Args::parse(&sv(&["--job", "first"])).unwrap().job().is_err());
    }

    #[test]
    fn map_flags_parse() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.max_candidates().unwrap(), 512);
        assert_eq!(a.single_group().unwrap(), SweepGroup::Original);
        let a =
            Args::parse(&sv(&["--max-candidates", "32", "--group", "D=50%", "--json"])).unwrap();
        assert_eq!(a.max_candidates().unwrap(), 32);
        assert_eq!(a.single_group().unwrap(), SweepGroup::Density(50));
        assert!(a.flag("json"));
        assert!(Args::parse(&sv(&["--max-candidates", "0"]))
            .unwrap()
            .max_candidates()
            .is_err());
        assert!(Args::parse(&sv(&["--group", "Orig,D=50%"]))
            .unwrap()
            .single_group()
            .is_err());
    }

    #[test]
    fn retries_and_conn_timeout_parsing() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.retries().unwrap(), 0);
        assert_eq!(a.conn_timeout_secs().unwrap(), 0);
        let a = Args::parse(&sv(&["--retries", "3", "--conn-timeout-secs", "15"])).unwrap();
        assert_eq!(a.retries().unwrap(), 3);
        assert_eq!(a.conn_timeout_secs().unwrap(), 15);
        assert!(Args::parse(&sv(&["--retries", "many"]))
            .unwrap()
            .retries()
            .is_err());
        assert!(Args::parse(&sv(&["--conn-timeout-secs", "-1"]))
            .unwrap()
            .conn_timeout_secs()
            .is_err());
    }

    #[test]
    fn max_queued_parsing() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.max_queued().unwrap(), crate::serve::server::DEFAULT_MAX_QUEUED);
        let a = Args::parse(&sv(&["--max-queued", "3"])).unwrap();
        assert_eq!(a.max_queued().unwrap(), 3);
        assert!(Args::parse(&sv(&["--max-queued", "0"]))
            .unwrap()
            .max_queued()
            .is_err());
        assert!(Args::parse(&sv(&["--max-queued", "lots"]))
            .unwrap()
            .max_queued()
            .is_err());
    }

    #[test]
    fn ring_spec_prefers_the_flag() {
        let a = Args::parse(&sv(&["--ring", "127.0.0.1:1,127.0.0.1:2"])).unwrap();
        assert_eq!(a.ring_spec().as_deref(), Some("127.0.0.1:1,127.0.0.1:2"));
    }

    #[test]
    fn store_cap_parsing() {
        assert_eq!(Args::parse(&[]).unwrap().store_cap_mb().unwrap(), None);
        let a = Args::parse(&sv(&["--store-cap-mb", "256"])).unwrap();
        assert_eq!(a.store_cap_mb().unwrap(), Some(256));
        assert!(Args::parse(&sv(&["--store-cap-mb", "0"]))
            .unwrap()
            .store_cap_mb()
            .is_err());
        assert!(Args::parse(&sv(&["--store-cap-mb", "lots"]))
            .unwrap()
            .store_cap_mb()
            .is_err());
    }
}
