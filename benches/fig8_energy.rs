//! Bench + regeneration harness for **Fig 8** (energy across models ×
//! sweep groups × designs) and the §V-D breakdown claims.
//!
//! `cargo bench --bench fig8_energy`

use codr::coordinator::{headline, run_sweep, Arch};
use codr::models::{all_models, SweepGroup};
use codr::report::{fig8_report, headline_report};
use codr::util::bench::Bencher;

fn main() {
    let models = all_models();
    let groups = SweepGroup::all();
    let results = run_sweep(&models, &groups, &Arch::all(), 42);
    let names: Vec<&str> = models.iter().map(|m| m.name).collect();
    println!("{}", fig8_report(&results, &names, &groups));
    println!("{}", headline_report(&results, &names).expect("full grid"));

    // --- §V-D / abstract shape checks.
    let h = headline(&results, &names).expect("full grid");
    assert!(h.energy_vs_ucnn > 2.0, "energy vs UCNN {}", h.energy_vs_ucnn);
    assert!(h.energy_vs_scnn > 2.0, "energy vs SCNN {}", h.energy_vs_scnn);
    // Paper order: SCNN consumes more than UCNN.
    assert!(
        h.energy_vs_scnn > h.energy_vs_ucnn,
        "SCNN {} should exceed UCNN {}",
        h.energy_vs_scnn,
        h.energy_vs_ucnn
    );
    for m in &names {
        let e = |a| results.get(m, SweepGroup::Original, a).unwrap().energy();
        let codr = e(Arch::Codr);
        // ALU is a significant CoDR consumer (paper ≈42%; our synthetic
        // weights compress less, so DRAM takes a bigger share — see
        // EXPERIMENTS.md §Fig8) because memory access was minimized;
        // crossbar is the smallest everywhere.
        assert!(codr.alu_uj / codr.total_uj() > 0.05, "{m}: CoDR ALU share");
        assert!(codr.xbar_uj < codr.alu_uj, "{m}: xbar vs ALU");
        // Energy drops with density degradation for every design.
        let orig = e(Arch::Codr).total_uj();
        let sparse = results
            .get(m, SweepGroup::Density(25), Arch::Codr)
            .unwrap()
            .energy()
            .total_uj();
        assert!(sparse < orig, "{m}: D=25% energy should drop");
    }
    println!("shape checks OK: ordering, ALU share, density trend\n");

    // --- timing: pricing the full grid (heavyweight — few iterations).
    let mut b = Bencher::with(2, 3, std::time::Duration::from_secs(30), 0);
    b.bench("full_grid_sweep_3models_6groups_3archs", || {
        run_sweep(&models, &groups, &Arch::all(), 11).results.len()
    });
    b.report("fig8 sweep timings");
}
