//! Bench for **Table I**: print the three designs' tiling parameters and
//! measure what they imply — cycle counts (and multiplies/cycle) on a
//! representative conv layer at equal area.
//!
//! `cargo bench --bench table1_throughput`

use codr::coordinator::Arch;
use codr::models::{synthesize_weights, LayerKind, LayerSpec};
use codr::report::table1_report;
use codr::util::bench::Bencher;
use codr::util::rng::Rng;

fn main() {
    println!("{}", table1_report());

    // Representative GoogleNet-class layer.
    let spec = LayerSpec {
        name: "rep_3x3".into(),
        kind: LayerKind::Conv,
        n: 128,
        m: 128,
        r_i: 28,
        r_k: 3,
        stride: 1,
        pad: 1,
        sigma_q: 2.0,
        zero_frac: 0.55,
    };
    let mut rng = Rng::new(42);
    let w = synthesize_weights(&spec, &mut rng);
    let dense_macs = spec.macs();

    println!(
        "{:<6} {:>8} {:>12} {:>14} {:>16}",
        "design", "mults", "cycles", "MACs/cycle", "dense-MACs/cycle"
    );
    for &arch in &Arch::all() {
        let acc = arch.build();
        let r = acc.simulate_layer(&spec, &w);
        println!(
            "{:<6} {:>8} {:>12} {:>14.1} {:>16.1}",
            arch.name(),
            acc.tile_config().total_mults(),
            r.cycles,
            r.alu.mults() as f64 / r.cycles as f64,
            dense_macs as f64 / r.cycles as f64,
        );
    }
    println!("\n(equal-area configs: effective throughput reflects how much");
    println!(" computation each design's reuse eliminates)\n");

    // --- timing the cycle model itself.
    let mut b = Bencher::new();
    for &arch in &Arch::all() {
        let w2 = w.clone();
        let s2 = spec.clone();
        b.bench(&format!("cycle_model_{}", arch.name()), move || {
            arch.build().simulate_layer(&s2, &w2).cycles
        });
    }
    b.report("table1 cycle-model timings");
}
