//! Bench + regeneration harness for **Fig 6** (weight compression rate
//! across models × sweep groups × designs). Prints the figure's series
//! and times the compression pipeline itself.
//!
//! `cargo bench --bench fig6_compression`

use codr::coordinator::{run_sweep, Arch};
use codr::models::{all_models, model_by_name, SweepGroup};
use codr::report::fig6_report;
use codr::util::bench::Bencher;

fn main() {
    // --- regenerate the figure (full grid, all three models).
    let models = all_models();
    let groups = SweepGroup::all();
    let results = run_sweep(&models, &groups, &Arch::all(), 42);
    let names: Vec<&str> = models.iter().map(|m| m.name).collect();
    println!("{}", fig6_report(&results, &names, &groups));

    // Paper anchors: CoDR compresses more than UCNN more than SCNN in the
    // left/middle groups, and the advantage grows when unique weights are
    // limited (left) — assert the shape so `cargo bench` fails loudly if
    // a regression flips it.
    for m in &names {
        for g in [SweepGroup::Unique(16), SweepGroup::Unique(64), SweepGroup::Original] {
            let rate = |a| {
                results
                    .get(m, g, a)
                    .map(|r| r.compression().rate())
                    .unwrap_or(0.0)
            };
            assert!(
                rate(Arch::Codr) > rate(Arch::Ucnn),
                "{m}/{}: CoDR {} <= UCNN {}",
                g.label(),
                rate(Arch::Codr),
                rate(Arch::Ucnn)
            );
        }
    }
    println!("shape check OK: CoDR > UCNN compression on U/orig groups\n");

    // --- timing: customized-RLE encode of one full model.
    let mut b = Bencher::heavy();
    let alexnet = model_by_name("alexnet").unwrap();
    b.bench("rle_encode_alexnet_full", || {
        let wl = codr::models::Workload::generate(&alexnet, None, None, 7);
        let cfg = codr::arch::TileConfig::codr();
        let mut total = 0usize;
        for (spec, w) in wl.conv_layers() {
            let tiled = codr::reuse::transform_layer(spec, w, cfg.t_n, cfg.t_m);
            let vs: Vec<codr::reuse::UcrVector> =
                tiled.iter().flat_map(|(_, v)| v.iter().cloned()).collect();
            let enc = codr::rle::encode_layer(
                &vs,
                codr::rle::CoderSpec::new(cfg.t_m * spec.r_k * spec.r_k),
            );
            total += enc.total_bits();
        }
        total
    });
    b.report("fig6 pipeline timings");
}
