//! Ablation bench: isolate each CoDR design choice DESIGN.md calls out.
//!
//! * **A1 — customized RLE vs fixed parameters**: the per-layer parameter
//!   search vs UCNN-style fixed bit-length 5 (same streams otherwise).
//! * **A2 — differential computation on/off**: ALU energy with Δ-width
//!   multiplies vs all-full-precision multiplies.
//! * **A3 — loop ordering**: CoDR's input/output-stationary nest vs a
//!   weight-stationary nest (features re-read per weight pass), on the
//!   same compressed weights.
//!
//! `cargo bench --bench ablation`

use codr::arch::{CactiLite, MemConfig, MemoryKind, MemoryStats};
use codr::energy::{price_layer, AluStats};
use codr::models::{googlenet, Workload};
use codr::rle::{LayerHistograms, RleParams};
use codr::util::bench::Bencher;

fn main() {
    let model = googlenet();
    let wl = Workload::generate(&model, None, None, 42);
    let cfg = codr::arch::TileConfig::codr();
    let cacti = CactiLite::default();
    let mem_cfg = MemConfig::default();

    // ---- A1: customized vs fixed RLE parameters --------------------------
    let mut best_bits = 0u64;
    let mut fixed_bits = 0u64;
    for (spec, w) in wl.conv_layers() {
        let tiled = codr::reuse::transform_layer(spec, w, cfg.t_n, cfg.t_m);
        let coder = codr::rle::CoderSpec::new(cfg.t_m * spec.r_k * spec.r_k);
        let mut hist = LayerHistograms::new(coder);
        for (_, vs) in &tiled {
            for u in vs {
                hist.add_vector(u);
            }
        }
        best_bits += hist.total_bits(hist.best_params());
        fixed_bits += hist.total_bits(RleParams {
            delta_bits: 5,
            count_bits: 5,
            index_bits: 5,
            header_bits: 5,
        });
    }
    let gain = fixed_bits as f64 / best_bits as f64;
    println!("A1 customized-RLE gain over fixed-5 params: {gain:.3}x");
    assert!(gain > 1.0, "parameter search must never lose");

    // ---- A2: differential computation on/off -----------------------------
    let design = codr::codr::Codr::default();
    let mut with_diff = 0.0;
    let mut without_diff = 0.0;
    for (spec, w) in wl.conv_layers() {
        let r = codr::sim::Accelerator::simulate_layer(&design, spec, w);
        with_diff += r.energy.alu_uj;
        // Ablated: every multiply at full precision.
        let ablated = AluStats {
            mults_full: r.alu.mults(),
            mults_low: 0,
            ..r.alu
        };
        without_diff += price_layer(&MemoryStats::default(), &ablated, &cacti, &mem_cfg).alu_uj;
    }
    println!(
        "A2 differential computation ALU saving: {:.3}x ({:.0} vs {:.0} µJ)",
        without_diff / with_diff,
        without_diff,
        with_diff
    );
    assert!(without_diff > with_diff);

    // ---- A3: loop ordering ------------------------------------------------
    // CoDR nest vs weight-stationary: weights read once, but features
    // re-read once per (output-channel, kernel-offset) pass.
    let mut codr_feat_pj = 0.0;
    let mut ws_feat_pj = 0.0;
    for (spec, w) in wl.conv_layers() {
        let r = codr::sim::Accelerator::simulate_layer(&design, spec, w);
        let mut feat_only = MemoryStats::default();
        feat_only.input_sram = r.mem.input_sram;
        feat_only.output_sram = r.mem.output_sram;
        codr_feat_pj +=
            price_layer(&feat_only, &AluStats::default(), &cacti, &mem_cfg).sram_uj;
        // Weight stationary: every weight held while its input window
        // streams → inputs read R_K² times, outputs accumulated
        // (read+write) once per input-channel tile.
        let mut ws = MemoryStats::default();
        ws.record(
            MemoryKind::InputSram,
            (spec.input_features() * spec.r_k * spec.r_k) as u64,
            8,
        );
        ws.record(
            MemoryKind::OutputSram,
            2 * (spec.output_features() * spec.n.div_ceil(cfg.t_n)) as u64,
            16,
        );
        ws_feat_pj += price_layer(&ws, &AluStats::default(), &cacti, &mem_cfg).sram_uj;
    }
    println!(
        "A3 feature-SRAM energy, CoDR nest vs weight-stationary: {:.0} vs {:.0} µJ ({:.2}x)",
        codr_feat_pj,
        ws_feat_pj,
        ws_feat_pj / codr_feat_pj
    );

    // ---- timings ----------------------------------------------------------
    let mut b = Bencher::heavy();
    let (spec0, w0) = wl.conv_layers().nth(5).map(|(s, w)| (s.clone(), w.clone())).unwrap();
    b.bench("simulate_one_inception_layer", || {
        codr::sim::Accelerator::simulate_layer(&design, &spec0, &w0).cycles
    });
    b.report("ablation timings");
}
