//! Bench + regeneration harness for **Fig 7** (SRAM accesses by data type
//! on GoogleNet, across sweep groups) and the §V-C access-detail claims.
//!
//! `cargo bench --bench fig7_sram`

use codr::coordinator::{run_sweep, Arch};
use codr::models::{googlenet, SweepGroup};
use codr::report::{fig7_report, sram_detail_report};
use codr::util::bench::Bencher;

fn main() {
    let model = googlenet();
    let groups = SweepGroup::all();
    let results = run_sweep(
        &[model.clone()],
        &groups,
        &Arch::all(),
        42,
    );
    println!("{}", fig7_report(&results, "googlenet", &groups));
    println!("{}", sram_detail_report(&results, &model));

    // Paper anchors (§III-B, §V-C) asserted as shape checks:
    let get = |g, a| results.get("googlenet", g, a).unwrap().mem();
    let codr = get(SweepGroup::Original, Arch::Codr);
    let ucnn = get(SweepGroup::Original, Arch::Ucnn);
    let scnn = get(SweepGroup::Original, Arch::Scnn);
    // CoDR accesses each output feature exactly once.
    let out_feats: u64 = model
        .conv_layers()
        .map(|l| l.output_features() as u64)
        .sum();
    assert_eq!(codr.output_sram.accesses, out_feats);
    // UCNN/SCNN read inputs ~20× more (paper: 20.4× / 21.3×).
    let ratio_u = ucnn.input_sram.accesses as f64 / codr.input_sram.accesses as f64;
    let ratio_s = scnn.input_sram.accesses as f64 / codr.input_sram.accesses as f64;
    assert!((15.0..30.0).contains(&ratio_u), "UCNN input ratio {ratio_u}");
    assert!((15.0..30.0).contains(&ratio_s), "SCNN input ratio {ratio_s}");
    // CoDR spends ~half its SRAM bandwidth on (cheap) weights; UCNN ~1-5%.
    assert!(codr.weight_bw_fraction() > 0.25, "{}", codr.weight_bw_fraction());
    assert!(ucnn.weight_bw_fraction() < 0.10, "{}", ucnn.weight_bw_fraction());
    // Totals: both baselines far above CoDR, SCNN worst (paper order).
    assert!(ucnn.sram_accesses() > 4 * codr.sram_accesses());
    assert!(scnn.sram_accesses() > ucnn.sram_accesses());
    println!("shape checks OK: output-once, input ~20x, weight BW split\n");

    // --- timing: one full-model dataflow simulation per design.
    let mut b = Bencher::heavy();
    for &arch in &Arch::all() {
        let m = model.clone();
        b.bench(&format!("simulate_googlenet_{}", arch.name()), || {
            let wl = codr::models::Workload::generate(&m, None, None, 7);
            let acc = arch.build();
            codr::sim::simulate_model(acc.as_ref(), &wl, "bench").cycles()
        });
    }
    b.report("fig7 simulation timings");
}
