//! Micro-benchmarks for the hot paths of the compression stack (the
//! §Perf optimization targets): UCR transform, histogram collection,
//! parameter search, encode, and decode throughput.
//!
//! `cargo bench --bench rle_codec`

use codr::models::{synthesize_weights, LayerKind, LayerSpec};
use codr::reuse::{transform_layer, UcrVector};
use codr::rle::{decode_layer, encode_layer, CoderSpec, LayerHistograms};
use codr::util::bench::Bencher;
use codr::util::rng::Rng;

fn main() {
    let spec = LayerSpec {
        name: "bench".into(),
        kind: LayerKind::Conv,
        n: 256,
        m: 256,
        r_i: 14,
        r_k: 3,
        stride: 1,
        pad: 1,
        sigma_q: 10.0,
        zero_frac: 0.6,
    };
    let mut rng = Rng::new(42);
    let w = synthesize_weights(&spec, &mut rng);
    let n_weights = spec.num_weights();
    let coder = CoderSpec::new(4 * 9);

    let tiled = transform_layer(&spec, &w, 4, 4);
    let vectors: Vec<UcrVector> = tiled.iter().flat_map(|(_, v)| v.iter().cloned()).collect();
    let enc = encode_layer(&vectors, coder);
    let lens: Vec<usize> = tiled
        .iter()
        .flat_map(|(t, _)| t.vectors.iter().map(|v| v.len()))
        .collect();
    println!(
        "layer: {} weights → {} bits ({:.2} b/w), {} vectors\n",
        n_weights,
        enc.total_bits(),
        enc.total_bits() as f64 / n_weights as f64,
        vectors.len()
    );

    let mut b = Bencher::new();
    b.bench("ucr_transform_590k_weights", || {
        transform_layer(&spec, &w, 4, 4).len()
    });
    b.bench("histograms_590k_weights", || {
        let mut h = LayerHistograms::new(coder);
        for u in &vectors {
            h.add_vector(u);
        }
        h.n_uniques
    });
    b.bench("param_search", || {
        let mut h = LayerHistograms::new(coder);
        for u in &vectors {
            h.add_vector(u);
        }
        h.best_params()
    });
    b.bench("encode_590k_weights", || {
        encode_layer(&vectors, coder).total_bits()
    });
    b.bench("decode_590k_weights", || {
        decode_layer(&enc, &lens).len()
    });
    let s = b.results().last().unwrap().median();
    let mbps = n_weights as f64 / s.as_secs_f64() / 1e6;
    b.report("rle codec timings");
    println!("\ndecode throughput ≈ {mbps:.1} M weights/s");
}
