//! End-to-end hot-path benchmark: the seed (reference) simulation
//! pipeline versus the fingerprint-memoized, emission-free one, over
//! the full (model × group × arch × layer) grid.
//!
//! Thin wrapper over the `codr bench` subcommand so `cargo bench --bench
//! hotpath` and the CLI produce the same `BENCH_hotpath.json` (format
//! v2: per-pass L1/L2 memo breakdown, lock-wait counters, and
//! extract / transform / price phase wall times):
//!
//! ```text
//! cargo bench --bench hotpath -- --quick --out /tmp/hotpath.json
//! ```

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match codr::cli::Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    match codr::cli::commands::bench(&args) {
        Ok(summary) => println!("{summary}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
