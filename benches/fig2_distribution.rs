//! Bench + regeneration harness for **Fig 2** (distribution of zero
//! weights and sorted-weight Δs at 8- and 16-bit across the three models).
//!
//! `cargo bench --bench fig2_distribution`

use codr::models::{all_models, Workload};
use codr::report::fig2_report;
use codr::reuse::stats::{model_distribution_16bit, model_distribution_8bit};
use codr::util::bench::Bencher;

fn main() {
    let models = all_models();
    println!("{}", fig2_report(&models, 42));

    // --- paper anchors as shape checks.
    let dist8 = |name: &str| {
        let m = models.iter().find(|m| m.name == name).unwrap();
        model_distribution_8bit(&Workload::generate(m, None, None, 42), 4, 4)
    };
    let vgg = dist8("vgg16");
    let goog = dist8("googlenet");
    let alex = dist8("alexnet");
    assert!(vgg.zero > goog.zero && vgg.zero > alex.zero, "VGG sparsest");
    assert!(
        goog.delta_zero > alex.delta_zero && goog.delta_zero > vgg.delta_zero,
        "GoogleNet most repetitive"
    );
    // 16-bit: sparsity and repetition collapse, small Δs remain (§II-C).
    let g16 = model_distribution_16bit(
        models.iter().find(|m| m.name == "googlenet").unwrap(),
        42,
        4,
        4,
    );
    assert!(g16.zero < 0.02 && g16.delta_zero < goog.delta_zero);
    assert!(g16.delta_small + g16.delta_mid > 0.3);
    println!("shape checks OK: Fig 2 orderings and 16-bit collapse\n");

    // --- timing.
    let mut b = Bencher::heavy();
    for m in &models {
        let mc = m.clone();
        b.bench(&format!("distribution_8bit_{}", m.name), || {
            let wl = Workload::generate(&mc, None, None, 7);
            model_distribution_8bit(&wl, 4, 4)
        });
    }
    b.report("fig2 analysis timings");
}
