//! Density ablation (the paper's right-side sweep groups, §V-A): degrade
//! weight density from the original to 25% and watch compression, SRAM
//! traffic and energy respond per design — the Fig 6/7/8 x-axis.
//!
//! ```sh
//! cargo run --release --example sweep_density -- [model] [seed]
//! ```

use codr::coordinator::{run_sweep, Arch};
use codr::models::{model_by_name, SweepGroup};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model_name = args.first().map(|s| s.as_str()).unwrap_or("googlenet");
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(42);
    let model = model_by_name(model_name)
        .or_else(|| (model_name == "tiny").then(codr::models::tiny_cnn))
        .expect("unknown model");

    let groups = [
        SweepGroup::Original,
        SweepGroup::Density(75),
        SweepGroup::Density(50),
        SweepGroup::Density(25),
    ];
    println!("density sweep on {model_name} (seed {seed})\n");
    let results = run_sweep(&[model.clone()], &groups, &Arch::all(), seed);

    println!(
        "{:<8} {:<6} {:>9} {:>14} {:>14} {:>12}",
        "group", "arch", "bits/w", "SRAM accesses", "multiplies", "energy µJ"
    );
    for &g in &groups {
        for &a in &Arch::all() {
            let r = results.get(model.name, g, a).unwrap();
            println!(
                "{:<8} {:<6} {:>9.2} {:>14} {:>14} {:>12.0}",
                g.label(),
                a.name(),
                r.compression().bits_per_weight(),
                r.mem().sram_accesses(),
                r.alu().mults(),
                r.energy().total_uj()
            );
        }
        println!();
    }
    println!("expected shape (paper Figs 6–8): all designs improve with");
    println!("sparsity; CoDR keeps the lowest energy at every point.");
}
