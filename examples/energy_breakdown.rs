//! Energy breakdown analysis (paper §V-D): where each design spends its
//! energy — DRAM / SRAM / RF / ALU / crossbar — and the §V-D percentage
//! claims (SCNN's DRAM share is the largest; ALU dominates CoDR; the
//! crossbar is the smallest consumer everywhere).
//!
//! ```sh
//! cargo run --release --example energy_breakdown -- [model]
//! ```

use codr::coordinator::{run_sweep, Arch};
use codr::models::{model_by_name, SweepGroup};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model_name = args.first().map(|s| s.as_str()).unwrap_or("googlenet");
    let model = model_by_name(model_name)
        .or_else(|| (model_name == "tiny").then(codr::models::tiny_cnn))
        .expect("unknown model");

    let results = run_sweep(&[model.clone()], &[SweepGroup::Original], &Arch::all(), 42);
    println!("energy breakdown, {model_name} (original weights)\n");
    println!(
        "{:<6} {:>10} {:>7} | {:>6} {:>6} {:>6} {:>6} {:>6}",
        "arch", "total µJ", "vs CoDR", "DRAM%", "SRAM%", "RF%", "ALU%", "xbar%"
    );
    let codr_total = results
        .get(model.name, SweepGroup::Original, Arch::Codr)
        .unwrap()
        .energy()
        .total_uj();
    for &a in &Arch::all() {
        let e = results
            .get(model.name, SweepGroup::Original, a)
            .unwrap()
            .energy();
        let t = e.total_uj();
        println!(
            "{:<6} {:>10.0} {:>6.2}x | {:>5.1}% {:>5.1}% {:>5.1}% {:>5.1}% {:>5.1}%",
            a.name(),
            t,
            t / codr_total,
            100.0 * e.dram_uj / t,
            100.0 * e.sram_uj / t,
            100.0 * e.rf_uj / t,
            100.0 * e.alu_uj / t,
            100.0 * e.xbar_uj / t,
        );
    }
    println!("\npaper §V-D anchors: DRAM is SCNN's largest share; ALU");
    println!("dominates CoDR (≈42%); crossbar is the smallest everywhere.");
}
