//! Quickstart: compress one conv layer with the customized RLE, simulate
//! it on all three designs, and print the comparison.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use codr::baselines::{Scnn, Ucnn};
use codr::codr::Codr;
use codr::models::{synthesize_weights, LayerKind, LayerSpec};
use codr::sim::Accelerator;
use codr::util::rng::Rng;

fn main() {
    // A GoogleNet-like 3×3 conv layer.
    let spec = LayerSpec {
        name: "demo_conv".into(),
        kind: LayerKind::Conv,
        n: 96,
        m: 128,
        r_i: 28,
        r_k: 3,
        stride: 1,
        pad: 1,
        sigma_q: 2.0,
        zero_frac: 0.55,
    };
    let mut rng = Rng::new(42);
    let weights = synthesize_weights(&spec, &mut rng);
    println!(
        "layer {}: {} weights, density {:.2}, {} unique non-zeros\n",
        spec.name,
        spec.num_weights(),
        codr::quant::density(weights.data()),
        codr::quant::unique_nonzero(weights.data()),
    );

    let designs: Vec<Box<dyn Accelerator>> = vec![
        Box::new(Codr::default()),
        Box::new(Ucnn::default()),
        Box::new(Scnn::default()),
    ];
    println!(
        "{:<6} {:>9} {:>12} {:>12} {:>12} {:>10}",
        "design", "bits/w", "SRAM acc", "mults", "cycles", "energy µJ"
    );
    for d in &designs {
        let r = d.simulate_layer(&spec, &weights);
        println!(
            "{:<6} {:>9.2} {:>12} {:>12} {:>12} {:>10.1}",
            d.name(),
            r.compression.bits_per_weight(),
            r.mem.sram_accesses(),
            r.alu.mults(),
            r.cycles,
            r.energy.total_uj()
        );
    }
    println!("\n(see `codr figure all` for the full paper reproduction)");
}
