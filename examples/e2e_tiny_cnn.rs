//! **End-to-end driver** (EXPERIMENTS.md §E2E): run a real CNN inference
//! through the entire stack and prove all layers compose:
//!
//! 1. synthesize a quantized CNN (the `tiny` zoo model) and inputs;
//! 2. compress every conv layer with UCR + customized RLE;
//! 3. execute inference through the CoDR *compressed datapath* — decode,
//!    differential scalar-matrix multiply, index routing, accumulate —
//!    plus ReLU / requantize / maxpool / FC;
//! 4. execute the same inference through the AOT-compiled JAX/Pallas
//!    artifact (`artifacts/cnn_fwd.hlo.txt`) on the PJRT CPU client;
//! 5. demand bit-for-bit equality on the logits, and report the
//!    architecture metrics (accesses, energy, cycles) for the run.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_tiny_cnn
//! ```

use codr::codr::Codr;
use codr::models::{tiny_cnn, Workload};
use codr::runtime::golden::{golden_report, run_tiny_cnn_e2e};
use codr::sim::simulate_model;
use std::path::Path;
use std::time::Instant;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    }

    // --- functional end-to-end: simulator vs compiled golden model.
    let t0 = Instant::now();
    let e2e = run_tiny_cnn_e2e(dir, 42).expect("e2e run failed");
    let dt = t0.elapsed();
    println!("tiny CNN inference through the compressed datapath:");
    println!("  simulator logits: {:?}", e2e.logits_sim);
    println!("  golden logits:    {:?}", e2e.logits_golden);
    println!(
        "  bit-for-bit: {}   ({dt:?} wall incl. PJRT compile)",
        if e2e.exact { "EXACT" } else { "MISMATCH" }
    );
    assert!(e2e.exact, "simulator and XLA golden model disagree");

    // --- per-layer golden checks across all artifact geometries.
    println!();
    match golden_report(dir, 42) {
        Ok(r) => print!("{r}"),
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(1);
        }
    }

    // --- architecture metrics for the same model on the CoDR design.
    let wl = Workload::generate(&tiny_cnn(), None, None, 42);
    let design = Codr::default();
    let res = simulate_model(&design, &wl, "e2e");
    let mem = res.mem();
    let e = res.energy();
    println!("\nCoDR architecture metrics (tiny CNN conv layers):");
    println!(
        "  compression: {:.2} bits/weight ({:.2}x vs dense 8-bit)",
        res.compression().bits_per_weight(),
        res.compression().rate()
    );
    println!(
        "  SRAM accesses: {} (weight {} / input {} / output {})",
        mem.sram_accesses(),
        mem.weight_sram.accesses,
        mem.input_sram.accesses,
        mem.output_sram.accesses
    );
    println!("  cycles: {}", res.cycles());
    println!(
        "  energy: {:.2} µJ (DRAM {:.2} SRAM {:.2} RF {:.2} ALU {:.2} xbar {:.3})",
        e.total_uj(),
        e.dram_uj,
        e.sram_uj,
        e.rf_uj,
        e.alu_uj,
        e.xbar_uj
    );
    println!("\nE2E OK — all layers compose.");
}
